"""Docs checks: intra-repo markdown links resolve; doctest code snippets run.

Used by the CI ``docs`` job and by ``tests/test_docs.py``:

    PYTHONPATH=src python tools/check_docs.py [paths...]

With no arguments, checks every ``*.md`` under ``docs/`` plus the top-level
``README.md``.  Two checks per file:

- every relative markdown link ``[text](target)`` resolves to an existing
  file (anchors are stripped; ``http(s)``/``mailto`` links are skipped);
- every fenced ```` ```python ```` block containing ``>>>`` prompts is run
  through :mod:`doctest` (so the examples in the docs can't rot).
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def check_links(md_path: Path) -> list:
    """Return a list of 'file:link' strings for unresolvable links."""
    bad = []
    for target in _LINK.findall(md_path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (md_path.parent / rel).resolve().exists():
            bad.append(f"{md_path.relative_to(REPO)}:{target}")
    return bad


def check_doctests(md_path: Path) -> list:
    """doctest every ```python fence with >>> prompts; returns failures."""
    failures = []
    runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS)
    parser = doctest.DocTestParser()
    for i, snippet in enumerate(_FENCE.findall(md_path.read_text())):
        if ">>>" not in snippet:
            continue
        name = f"{md_path.relative_to(REPO)}[{i}]"
        test = parser.get_doctest(snippet, {}, name, str(md_path), 0)
        result = runner.run(test, clear_globs=True)
        if result.failed:
            failures.append(name)
    return failures


def doc_files(args: list) -> list:
    if args:
        return [Path(a).resolve() for a in args]
    files = sorted((REPO / "docs").glob("*.md"))
    readme = REPO / "README.md"
    return files + ([readme] if readme.exists() else [])


def main(argv: list) -> int:
    bad_links, bad_tests = [], []
    files = doc_files(argv)
    for md in files:
        bad_links += check_links(md)
        bad_tests += check_doctests(md)
    for b in bad_links:
        print(f"BROKEN LINK  {b}")
    for b in bad_tests:
        print(f"DOCTEST FAIL {b}")
    print(f"checked {len(files)} files: "
          f"{len(bad_links)} broken links, {len(bad_tests)} doctest failures")
    return 1 if (bad_links or bad_tests) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
