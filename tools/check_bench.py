"""Benchmark-artifact schema checks: BENCH_decode.json invariants.

Used by the CI ``docs`` job and runnable standalone:

    python tools/check_bench.py [path/to/BENCH_decode.json]

Beyond key/type presence, this asserts the two claims the artifact exists
to document (ISSUE 3 acceptance):

- the fused kernel stages each KV block once per GQA *group*: every kernel
  sweep row must show ``kv_fetches_unfused == group * kv_fetches_fused``;
- the on-device decode window amortizes dispatch: every ``decode_loop``
  row must show ``dispatches_per_token <= 1/window`` (one device dispatch
  per T-token window) and token-identical output vs the per-token path.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT = REPO / "BENCH_decode.json"

_TOP_KEYS = ("benchmark", "arch", "interpret", "kernel_sweep", "decode_loop")
_SWEEP_KEYS = ("b", "hq", "hkv", "group", "block_size", "num_blocks",
               "fused_us", "unfused_us", "kv_fetches_fused",
               "kv_fetches_unfused", "fetch_ratio")
_LOOP_KEYS = ("window", "dispatches_per_token", "us_per_token",
              "us_per_token_stepwise", "pool_donated", "tokens_match")


def check(path: Path) -> list:
    """Return a list of human-readable violations (empty == pass)."""
    bad = []
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    for k in _TOP_KEYS:
        if k not in doc:
            bad.append(f"missing top-level key {k!r}")
    if bad:
        return bad
    if doc["benchmark"] != "decode_micro":
        bad.append(f"benchmark != decode_micro: {doc['benchmark']!r}")
    if not doc["kernel_sweep"]:
        bad.append("kernel_sweep is empty")
    for i, row in enumerate(doc["kernel_sweep"]):
        missing = [k for k in _SWEEP_KEYS if k not in row]
        if missing:
            bad.append(f"kernel_sweep[{i}]: missing {missing}")
            continue
        g = row["hq"] // row["hkv"]
        if row["group"] != g:
            bad.append(f"kernel_sweep[{i}]: group {row['group']} != "
                       f"hq/hkv {g}")
        if row["kv_fetches_unfused"] != g * row["kv_fetches_fused"]:
            bad.append(
                f"kernel_sweep[{i}]: unfused fetches "
                f"{row['kv_fetches_unfused']} != group({g}) x fused "
                f"{row['kv_fetches_fused']} — the fused kernel must stage "
                "each KV block once per GQA group")
        if row["fetch_ratio"] != g:
            bad.append(f"kernel_sweep[{i}]: fetch_ratio {row['fetch_ratio']}"
                       f" != group {g}")
    if not doc["decode_loop"]:
        bad.append("decode_loop is empty")
    for i, row in enumerate(doc["decode_loop"]):
        missing = [k for k in _LOOP_KEYS if k not in row]
        if missing:
            bad.append(f"decode_loop[{i}]: missing {missing}")
            continue
        t = row["window"]
        if t >= 1 and row["dispatches_per_token"] > 1.0 / t + 1e-9:
            bad.append(
                f"decode_loop[{i}]: {row['dispatches_per_token']} dispatches"
                f"/token for window={t} — the scan must issue one device "
                "dispatch per window")
        if not row["tokens_match"]:
            bad.append(f"decode_loop[{i}]: window output is not token-"
                       "identical to the per-token path")
    return bad


def main(argv: list) -> int:
    path = Path(argv[0]) if argv else DEFAULT
    bad = check(path)
    for b in bad:
        print(f"BENCH SCHEMA  {b}")
    print(f"checked {path.name}: {len(bad)} violations")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
