"""Benchmark-artifact schema checks: BENCH_decode/BENCH_serving invariants.

Used by the CI jobs and runnable standalone:

    python tools/check_bench.py                       # both defaults
    python tools/check_bench.py path/to/BENCH_decode.json
    python tools/check_bench.py --serving BENCH_serving.json

Beyond key/type presence, this asserts the claims the artifacts exist to
document:

ISSUE 3 acceptance (``BENCH_decode.json``):

- the fused kernel stages each KV block once per GQA *group*: every kernel
  sweep row must show ``kv_fetches_unfused == group * kv_fetches_fused``;
- the on-device decode window amortizes dispatch: every ``decode_loop``
  row must show ``dispatches_per_token <= 1/window`` (one device dispatch
  per T-token window) and token-identical output vs the per-token path.

ISSUE 4 acceptance (``BENCH_serving.json``):

- the shared-prefix sweep shows ``prefix_hit_rate > 0`` with the cache on
  (and 0 for the un-shared baseline), every request served, and TTFT no
  worse than the baseline (the sweep is deterministic: fixed-cost
  executor on the virtual clock);
- the tight-pool sweep completes **every** request via preemption — zero
  RuntimeErrors, ``preemptions > 0`` — where worst-case-reservation
  admission would refuse the concurrency.

ISSUE 5 acceptance (``BENCH_serving.json`` ``fleet_sweep``):

- pinned tiers form a cost-vs-latency Pareto ladder: walking up the
  ranks, p50 latency never rises while $-cost strictly rises, and every
  pinned run serves every request;
- the mixed run uses **>= 3 distinct clone types**, escalates >= 1
  KV-hungry request up the ladder with output token-identical to the
  pinned-``large`` run, completes everything with zero RuntimeErrors
  (escalation absorbs KV pressure — no ``PoolExhausted`` crash), bills
  per-type clone-seconds / chips-aware energy / $-cost for every type it
  used, and powers off >= 1 long-idle secondary during the drain.

ISSUE 6 acceptance (chunked prefill + mixed dispatch, ADR-005):

- every ``prefill_loop`` row in ``BENCH_decode.json`` must show the
  chunked path strictly reducing sequential steps per suffix token vs
  the stepwise scan, a >= 4x reduction whenever ``chunk >= 8``, and
  token-identical output (first tokens *and* the decode continuation);
- the ``mixed_dispatch`` sweep in ``BENCH_serving.json`` must show the
  unified mixed prefill/decode dispatch holding the decode cohort's p99
  TPOT no worse than the no-join baseline under mid-stream joins, while
  the serial prefill-then-decode path degrades it, with every request
  served in all three runs.

ISSUE 7 acceptance (fault-injected serving, ADR-006):

- every ``fault_sweep`` row serves every request with tokens
  **bit-identical** to the faultless baseline — a clone death is a
  latency event, never a correctness event;
- the ``drain`` scenario recovers via KV **migration** to a survivor,
  the ``kill`` scenario via prefix-accelerated **restore**, each trips a
  circuit breaker, and p99 stays within ``_FAULT_P99_FACTOR``x of the
  faultless run;
- the ``slow_hedged`` scenario fires and wins >= 1 hedged duplicate and
  its p99 is no worse than the unhedged straggler run.

ISSUE 9 acceptance (cross-tier speculative decoding, ADR-008):

- every ``spec`` row in ``BENCH_decode.json`` is token-identical to
  stepwise greedy decode across the acceptance sweep, spends < 1 target
  dispatch per token, and the full-agreement (``flip_p == 0``) rows show
  a modeled cross-tier speedup >= 1;
- the ``spec`` sweep in ``BENCH_serving.json`` serves every request in
  every row, the speculative rows token-identical to the pinned-large
  baseline, with the oracle row at full acceptance, the corrupted row
  strictly between 0 and 1, and the oracle row at a strictly lower
  $-per-token than pinned-large without losing tokens/s.

ISSUE 10 acceptance (disaggregated prefill/decode, ADR-009):

- every ``disagg`` row in ``BENCH_serving.json`` serves every request;
  the disagg rows hand off >= 1 prefill to the shared partner, the
  uncompressed row is token-identical to the colocated-large baseline,
  the compressed row moves < 0.5x the uncompressed row's modeled KV
  transfer bytes, and ``disagg_compressed`` beats ``colocated_large``
  on $-per-token at an equal-or-better p99 TTFT;
- the ``disagg.affinity`` sub-sweep serves every request in both arms
  and prefix-affinity routing's hit rate strictly beats the seeded
  random placement control.

Every missing-section violation names the command that regenerates the
artifact, so a stale BENCH file is a one-line fix.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT = REPO / "BENCH_decode.json"
DEFAULT_SERVING = REPO / "BENCH_serving.json"

# regeneration commands, quoted in missing-section/unreadable violations
_REGEN_DECODE = "PYTHONPATH=src python benchmarks/decode_micro.py"
_REGEN_SERVING = "PYTHONPATH=src python benchmarks/serving_load.py"


def _regen(cmd: str) -> str:
    return f" (regenerate: {cmd})"


_TOP_KEYS = ("benchmark", "arch", "interpret", "kernel_sweep", "decode_loop",
             "prefill_loop", "spec")
_SWEEP_KEYS = ("b", "hq", "hkv", "group", "block_size", "num_blocks",
               "fused_us", "unfused_us", "kv_fetches_fused",
               "kv_fetches_unfused", "fetch_ratio")
_LOOP_KEYS = ("window", "dispatches_per_token", "us_per_token",
              "us_per_token_stepwise", "pool_donated", "tokens_match")
_PREFILL_KEYS = ("rows", "prefix_len", "suffix_len", "chunk", "tokens_total",
                 "dispatches_per_token", "dispatches_per_token_stepwise",
                 "tokens_per_s", "tokens_per_s_stepwise", "tokens_match")
_SPEC_KEYS = ("slots", "k_max", "budget", "flip_p", "draft_cost",
              "tokens_emitted", "rounds", "acceptance_rate",
              "dispatches_per_token", "spec_speedup", "tokens_match")


def _check_spec_decode(doc: dict) -> list:
    """``spec`` violations in BENCH_decode.json (ISSUE 9 acceptance)."""
    bad = []
    rows = doc["spec"]
    if not rows:
        return [f"spec is empty{_regen(_REGEN_DECODE)}"]
    for i, row in enumerate(rows):
        missing = [k for k in _SPEC_KEYS if k not in row]
        if missing:
            return bad + [f"spec[{i}]: missing {missing}"
                          f"{_regen(_REGEN_DECODE)}"]
        if not row["tokens_match"]:
            bad.append(f"spec[{i}] (flip_p={row['flip_p']}): speculative "
                       "decode is not token-identical to stepwise greedy "
                       "— speculation must be lossless at every "
                       "acceptance level")
        if row["dispatches_per_token"] > 1.0 + 1e-9:
            bad.append(f"spec[{i}]: {row['dispatches_per_token']} target "
                       "dispatches/token — a verify round must emit at "
                       "least one token")
        if row["flip_p"] == 0 and row["dispatches_per_token"] >= 1.0:
            bad.append(f"spec[{i}]: {row['dispatches_per_token']} target "
                       "dispatches/token at full agreement — speculation "
                       "never amortized a verify round over > 1 token")
        if row["flip_p"] == 0 and row["acceptance_rate"] < 1.0 - 1e-9:
            bad.append(f"spec[{i}]: oracle draft acceptance "
                       f"{row['acceptance_rate']} < 1.0 — the draft/verify "
                       "pair disagrees without corruption")
        if row["flip_p"] == 0 and row["spec_speedup"] < 1.0 - 1e-9:
            bad.append(f"spec[{i}]: modeled cross-tier speedup "
                       f"{row['spec_speedup']} < 1 at full agreement — "
                       "drafting on the cheap tier must pay for itself")
    if not any(r["flip_p"] == 0 for r in rows):
        bad.append("spec sweep has no flip_p=0 (full-agreement) row")
    if not any(r["flip_p"] > 0 for r in rows):
        bad.append("spec sweep has no corrupted row — partial acceptance "
                   "is unexercised")
    return bad


def check(path: Path) -> list:
    """Return a list of human-readable violations (empty == pass)."""
    bad = []
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e}){_regen(_REGEN_DECODE)}"]
    for k in _TOP_KEYS:
        if k not in doc:
            bad.append(f"missing top-level key {k!r}"
                       f"{_regen(_REGEN_DECODE)}")
    if bad:
        return bad
    if doc["benchmark"] != "decode_micro":
        bad.append(f"benchmark != decode_micro: {doc['benchmark']!r}")
    if not doc["kernel_sweep"]:
        bad.append("kernel_sweep is empty")
    for i, row in enumerate(doc["kernel_sweep"]):
        missing = [k for k in _SWEEP_KEYS if k not in row]
        if missing:
            bad.append(f"kernel_sweep[{i}]: missing {missing}")
            continue
        g = row["hq"] // row["hkv"]
        if row["group"] != g:
            bad.append(f"kernel_sweep[{i}]: group {row['group']} != "
                       f"hq/hkv {g}")
        if row["kv_fetches_unfused"] != g * row["kv_fetches_fused"]:
            bad.append(
                f"kernel_sweep[{i}]: unfused fetches "
                f"{row['kv_fetches_unfused']} != group({g}) x fused "
                f"{row['kv_fetches_fused']} — the fused kernel must stage "
                "each KV block once per GQA group")
        if row["fetch_ratio"] != g:
            bad.append(f"kernel_sweep[{i}]: fetch_ratio {row['fetch_ratio']}"
                       f" != group {g}")
    if not doc["decode_loop"]:
        bad.append("decode_loop is empty")
    for i, row in enumerate(doc["decode_loop"]):
        missing = [k for k in _LOOP_KEYS if k not in row]
        if missing:
            bad.append(f"decode_loop[{i}]: missing {missing}")
            continue
        t = row["window"]
        if t >= 1 and row["dispatches_per_token"] > 1.0 / t + 1e-9:
            bad.append(
                f"decode_loop[{i}]: {row['dispatches_per_token']} dispatches"
                f"/token for window={t} — the scan must issue one device "
                "dispatch per window")
        if not row["tokens_match"]:
            bad.append(f"decode_loop[{i}]: window output is not token-"
                       "identical to the per-token path")
    if not doc["prefill_loop"]:
        bad.append("prefill_loop is empty")
    for i, row in enumerate(doc["prefill_loop"]):
        missing = [k for k in _PREFILL_KEYS if k not in row]
        if missing:
            bad.append(f"prefill_loop[{i}]: missing {missing}")
            continue
        if row["dispatches_per_token"] >= row["dispatches_per_token_stepwise"]:
            bad.append(
                f"prefill_loop[{i}]: chunked prefill does not reduce "
                f"sequential steps/token ({row['dispatches_per_token']} vs "
                f"stepwise {row['dispatches_per_token_stepwise']})")
        if (row["chunk"] >= 8
                and row["dispatches_per_token"] * 4 >
                row["dispatches_per_token_stepwise"] + 1e-9):
            bad.append(
                f"prefill_loop[{i}]: chunk={row['chunk']} must cut "
                f"sequential steps/token >= 4x, got "
                f"{row['dispatches_per_token_stepwise'] / row['dispatches_per_token']:.2f}x")
        if not row["tokens_match"]:
            bad.append(f"prefill_loop[{i}]: chunked prefill is not token-"
                       "identical to the stepwise scan")
    bad += _check_spec_decode(doc)
    return bad


_SERVING_ROW_KEYS = ("rate_rps", "kv", "decode_window", "served", "shed",
                     "p50_latency_s", "p99_latency_s", "p50_ttft_s",
                     "tokens_per_s", "kv_util", "kv_reserved_peak_tokens",
                     "prefix_hit_rate", "preemptions", "restored_tokens",
                     "peak_secondaries", "busy_energy_j", "cost_usd",
                     "escalations", "power_offs")
_PREFIX_KEYS = ("prefix_cache", "prefix_len", "prefix_share", "served",
                "offered", "p50_ttft_s", "p99_latency_s", "p99_tpot_s",
                "prefix_hit_rate", "preemptions", "restored_tokens")
_TIGHT_KEYS = ("num_blocks", "offered", "served", "runtime_errors",
               "preemptions", "restored_tokens", "prefix_hit_rate")
_FLEET_PIN_KEYS = ("clone_type", "usd_per_hour", "tier_step_s", "served",
                   "offered", "runtime_errors", "p50_latency_s",
                   "p99_latency_s", "p50_ttft_s", "busy_energy_j",
                   "cost_usd", "clone_seconds_by_type")
_FLEET_MIX_KEYS = ("fleet", "base_type", "premium_type", "num_blocks",
                   "served", "offered", "runtime_errors", "escalations",
                   "fleet_mix", "distinct_types", "p50_latency_s",
                   "p99_latency_s", "cost_usd", "energy_j_by_type",
                   "clone_seconds_by_type", "power_offs",
                   "tokens_identical_to_pinned_large")


def _check_fleet(doc: dict) -> list:
    """``fleet_sweep`` violations (ISSUE 5 acceptance)."""
    bad = []
    sweep = doc.get("fleet_sweep")
    if not sweep:                   # optional: --fleet '' disables
        return bad
    for k in ("pinned", "mixed"):
        if k not in sweep:
            return [f"fleet_sweep: missing {k!r}"
                    f"{_regen(_REGEN_SERVING)}"]
    if len(sweep["pinned"]) < 2:
        bad.append("fleet_sweep.pinned needs >= 2 tiers for a Pareto")
    for i, row in enumerate(sweep["pinned"]):
        missing = [k for k in _FLEET_PIN_KEYS if k not in row]
        if missing:
            return bad + [f"fleet_sweep.pinned[{i}]: missing {missing}"]
        if row["runtime_errors"] != 0 or row["served"] != row["offered"]:
            bad.append(f"fleet_sweep.pinned[{i}] ({row['clone_type']}): "
                       f"served {row['served']}/{row['offered']} with "
                       f"{row['runtime_errors']} errors")
        if row["cost_usd"] <= 0:
            bad.append(f"fleet_sweep.pinned[{i}]: no $-cost billed")
    for a, b in zip(sweep["pinned"], sweep["pinned"][1:]):
        if b["p50_latency_s"] > a["p50_latency_s"] + 1e-9:
            bad.append(f"fleet Pareto broken: {b['clone_type']} is dearer "
                       f"AND slower than {a['clone_type']} "
                       f"({b['p50_latency_s']} > {a['p50_latency_s']})")
        if b["cost_usd"] <= a["cost_usd"]:
            bad.append(f"fleet Pareto degenerate: {b['clone_type']} not "
                       f"dearer than {a['clone_type']} — tier pricing "
                       "is not differentiating the ladder")
    mixed = sweep["mixed"]
    missing = [k for k in _FLEET_MIX_KEYS if k not in mixed]
    if missing:
        return bad + [f"fleet_sweep.mixed: missing {missing}"]
    if mixed["runtime_errors"] != 0:
        bad.append("mixed fleet run raised — escalated long-context "
                   "requests must complete without PoolExhausted/"
                   "RuntimeError")
    if mixed["served"] != mixed["offered"]:
        bad.append(f"mixed fleet run lost requests: {mixed['served']}/"
                   f"{mixed['offered']}")
    used = [t for t, n in mixed["fleet_mix"].items() if n > 0]
    if len(used) < 3 or mixed["distinct_types"] != len(used):
        bad.append(f"placement engine must serve across >= 3 distinct "
                   f"clone types, used {sorted(used)}")
    if mixed["escalations"] < 1:
        bad.append("no live type escalation in the mixed fleet run")
    if not mixed["tokens_identical_to_pinned_large"]:
        bad.append("escalated serving is not token-identical to the "
                   "pinned-large run")
    for t in used:
        if mixed["energy_j_by_type"].get(t, 0) <= 0:
            bad.append(f"no chips-aware energy billed for used type {t!r}")
        if mixed["clone_seconds_by_type"].get(t, 0) <= 0:
            bad.append(f"no clone-seconds billed for used type {t!r}")
    if mixed["cost_usd"] <= 0:
        bad.append("mixed fleet run billed no $-cost")
    if mixed["power_offs"] < 1:
        bad.append("OFF_IDLE_TTL never powered off an idle secondary "
                   "during the mixed run's drain")
    return bad


_MIXED_ROW_KEYS = ("prefill_chunk", "mixed_dispatch", "served", "offered",
                   "p50_ttft_s", "p99_tpot_s")


def _check_mixed(doc: dict) -> list:
    """``mixed_dispatch`` violations (ISSUE 6 acceptance)."""
    bad = []
    sweep = doc.get("mixed_dispatch")
    if not sweep:                   # optional: --mixed-requests 0 disables
        return bad
    for k in ("nojoin", "serial", "mixed"):
        if k not in sweep:
            return [f"mixed_dispatch: missing {k!r}"
                    f"{_regen(_REGEN_SERVING)}"]
        row = sweep[k]
        missing = [m for m in _MIXED_ROW_KEYS if m not in row]
        if missing:
            return [f"mixed_dispatch.{k}: missing {missing}"
                    f"{_regen(_REGEN_SERVING)}"]
        if row["served"] != row["offered"]:
            bad.append(f"mixed_dispatch.{k}: served {row['served']} != "
                       f"offered {row['offered']}")
    nojoin, serial, mixed = sweep["nojoin"], sweep["serial"], sweep["mixed"]
    if not mixed["mixed_dispatch"] or mixed["prefill_chunk"] < 1:
        bad.append("mixed_dispatch.mixed row did not run with chunked "
                   "prefill + unified dispatch enabled")
    if serial["mixed_dispatch"] or serial["prefill_chunk"] != 0:
        bad.append("mixed_dispatch.serial row must be the stepwise "
                   "prefill-then-decode path")
    # epsilon 1e-4: joins pay a modeled block-table upload (~1e-5 s) the
    # no-join baseline never does; the stall being ruled out is one
    # sequential scan step (0.05 s) per join round
    if mixed["p99_tpot_s"] > nojoin["p99_tpot_s"] + 1e-4:
        bad.append(
            f"mid-stream joins degraded decode p99 TPOT under mixed "
            f"dispatch: {mixed['p99_tpot_s']} vs no-join baseline "
            f"{nojoin['p99_tpot_s']} — one fused dispatch must not stall "
            "the decode cohort")
    if serial["p99_tpot_s"] <= nojoin["p99_tpot_s"] + 1e-4:
        bad.append(
            "serial prefill-then-decode shows no TPOT stall vs the "
            "no-join baseline — the sweep is not actually exercising "
            "join pressure")
    if not mixed.get("tokens_identical_to_serial", False):
        bad.append("mixed-dispatch serving is not token-identical to the "
                   "serial prefill-then-decode run")
    return bad


_FAULT_ROW_KEYS = ("scenario", "faults", "offered", "served",
                   "runtime_errors", "p50_latency_s", "p99_latency_s",
                   "faults_injected", "recoveries_migrated",
                   "recoveries_restored", "breaker_opens", "hedges_fired",
                   "hedge_wins", "tokens_identical_to_faultless")
# p99 under a mid-run clone death must stay within this factor of the
# faultless run: recovery (migration or prefix-accelerated restore) is a
# bounded latency event, not a retry storm
_FAULT_P99_FACTOR = 4.0


def _check_faults(doc: dict) -> list:
    """``fault_sweep`` violations (ISSUE 7 acceptance, ADR-006)."""
    bad = []
    sweep = doc.get("fault_sweep")
    if not sweep:                   # optional: --fault-requests 0 disables
        return bad
    by = {}
    for i, row in enumerate(sweep):
        missing = [k for k in _FAULT_ROW_KEYS if k not in row]
        if missing:
            return bad + [f"fault_sweep[{i}]: missing {missing}"
                          f"{_regen(_REGEN_SERVING)}"]
        by[row["scenario"]] = row
        if row["runtime_errors"] != 0:
            bad.append(f"fault_sweep.{row['scenario']}: raised — recovery "
                       "must absorb clone death, never crash")
        if row["served"] != row["offered"]:
            bad.append(f"fault_sweep.{row['scenario']}: lost requests "
                       f"({row['served']}/{row['offered']}) — no request "
                       "may be lost to a fault")
        if not row["tokens_identical_to_faultless"]:
            bad.append(f"fault_sweep.{row['scenario']}: output diverged "
                       "from the faultless run — recovery must be "
                       "token-identical")
    for k in ("baseline", "drain", "kill", "mixed", "slow_unhedged",
              "slow_hedged"):
        if k not in by:
            return bad + [f"fault_sweep: missing scenario {k!r}"
                          f"{_regen(_REGEN_SERVING)}"]
    base_p99 = by["baseline"]["p99_latency_s"]
    for k in ("drain", "kill", "mixed"):
        row = by[k]
        if row["faults_injected"] < 1:
            bad.append(f"fault_sweep.{k}: no fault actually injected")
        if row["recoveries_migrated"] + row["recoveries_restored"] < 1:
            bad.append(f"fault_sweep.{k}: fault injected but nothing "
                       "recovered — in-flight requests were not on the "
                       "dead clone or recovery never ran")
        if row["breaker_opens"] < 1:
            bad.append(f"fault_sweep.{k}: clone death never tripped a "
                       "circuit breaker")
        if row["p99_latency_s"] > _FAULT_P99_FACTOR * base_p99 + 1e-9:
            bad.append(f"fault_sweep.{k}: p99 {row['p99_latency_s']} "
                       f"exceeds {_FAULT_P99_FACTOR}x the faultless "
                       f"{base_p99} — recovery latency is unbounded")
    if by["drain"]["recoveries_migrated"] < 1:
        bad.append("fault_sweep.drain: graceful death never migrated KV "
                   "to a survivor")
    if by["kill"]["recoveries_restored"] < 1:
        bad.append("fault_sweep.kill: fail-stop never restored a request "
                   "via re-prefill")
    hedged, unhedged = by["slow_hedged"], by["slow_unhedged"]
    if hedged["hedges_fired"] < 1 or hedged["hedge_wins"] < 1:
        bad.append("fault_sweep.slow_hedged: hedged dispatch never fired/"
                   "won against the injected straggler")
    if unhedged["hedges_fired"] != 0:
        bad.append("fault_sweep.slow_unhedged: hedges fired with "
                   "hedge_factor=0")
    if hedged["p99_latency_s"] > unhedged["p99_latency_s"] + 1e-9:
        bad.append(f"fault_sweep: hedging raised p99 "
                   f"({hedged['p99_latency_s']} vs unhedged "
                   f"{unhedged['p99_latency_s']})")
    return bad


_OVERLOAD_ROW_KEYS = ("scenario", "rate_rps", "over", "gated", "offered",
                      "served", "p50_ttft_s", "p99_ttft_s",
                      "peak_queue_depth", "slo_attainment", "goodput_tps",
                      "shed", "shed_by_slo", "rejected", "retries",
                      "cache_hits", "faults_injected",
                      "tokens_identical_to_ungated")
# the ungated baseline's p99 TTFT must grow at least this much between
# consecutive overload factors — without admission control, queueing
# delay diverges past capacity
_OVERLOAD_P99_GROWTH = 1.3
# the gateway must hold interactive SLO attainment at or above this
# floor at every overload factor >= 1.5x capacity (ISSUE 8 acceptance)
_OVERLOAD_SLO_FLOOR = 0.95
# under fault + overload, the gated run's interactive attainment must
# beat the ungated faulted baseline by at least this margin
_OVERLOAD_FAULT_MARGIN = 0.15


def _check_gateway(doc: dict) -> list:
    """``overload_sweep`` violations (ISSUE 8 acceptance, ADR-007)."""
    bad = []
    sweep = doc.get("overload_sweep")
    if not sweep:               # optional: --overload-requests 0 disables
        return bad
    for k in ("link", "capacity_rps", "deadline_s", "rows"):
        if k not in sweep:
            return bad + [f"overload_sweep: missing top-level key {k!r}"
                          f"{_regen(_REGEN_SERVING)}"]
    rows = sweep["rows"]
    for i, row in enumerate(rows):
        missing = [k for k in _OVERLOAD_ROW_KEYS if k not in row]
        if missing:
            return bad + [f"overload_sweep[{i}]: missing {missing}"
                          f"{_regen(_REGEN_SERVING)}"]
    scenarios = {row["scenario"] for row in rows}
    for k in ("ungated", "gated", "fault_ungated", "fault_gated"):
        if k not in scenarios:
            return bad + [f"overload_sweep: missing scenario {k!r}"
                          f"{_regen(_REGEN_SERVING)}"]
    ungated = sorted((r for r in rows if r["scenario"] == "ungated"),
                     key=lambda r: r["over"])
    gated = {r["over"]: r for r in rows if r["scenario"] == "gated"}
    for lo, hi in zip(ungated, ungated[1:]):
        if hi["p99_ttft_s"] <= _OVERLOAD_P99_GROWTH * lo["p99_ttft_s"]:
            bad.append(f"overload_sweep: ungated p99 TTFT did not "
                       f"diverge past capacity ({lo['p99_ttft_s']} @ "
                       f"{lo['over']}x -> {hi['p99_ttft_s']} @ "
                       f"{hi['over']}x, need >{_OVERLOAD_P99_GROWTH}x "
                       "growth)")
        if hi["peak_queue_depth"] <= lo["peak_queue_depth"]:
            bad.append("overload_sweep: ungated peak queue depth stopped "
                       f"growing ({lo['peak_queue_depth']} @ {lo['over']}x"
                       f" -> {hi['peak_queue_depth']} @ {hi['over']}x) — "
                       "the sweep is not actually past capacity")
    for row in rows:
        if not row["gated"]:
            continue
        name = f"overload_sweep.{row['scenario']}@{row['over']}x"
        if "interactive" in row["shed_by_slo"]:
            bad.append(f"{name}: shed interactive work — load shedding "
                       "must only drop batch-class requests")
        if not row["tokens_identical_to_ungated"]:
            bad.append(f"{name}: admitted requests' outputs diverged "
                       "from the ungated run — gating must not change "
                       "what admitted work decodes")
    for over, row in gated.items():
        if row["cache_hits"] < 1:
            bad.append(f"overload_sweep.gated@{over}x: response cache "
                       "never hit despite duplicate prompts in the trace")
        slo_i = row["slo_attainment"].get("interactive", 0.0)
        if over >= 1.5 and slo_i < _OVERLOAD_SLO_FLOOR:
            bad.append(f"overload_sweep.gated@{over}x: interactive SLO "
                       f"attainment {slo_i} below the "
                       f"{_OVERLOAD_SLO_FLOOR} floor — the gateway is "
                       "not protecting interactive work under overload")
        twin = next((r for r in ungated if r["over"] == over), None)
        if (over >= 1.5 and twin is not None
                and row["goodput_tps"] < twin["goodput_tps"] - 1e-9):
            bad.append(f"overload_sweep.gated@{over}x: goodput "
                       f"{row['goodput_tps']} fell below the ungated "
                       f"{twin['goodput_tps']} — shedding must raise "
                       "deadline-meeting throughput, not lower it")
    fu = next(r for r in rows if r["scenario"] == "fault_ungated")
    fg = next(r for r in rows if r["scenario"] == "fault_gated")
    for name, row in (("fault_ungated", fu), ("fault_gated", fg)):
        if row["faults_injected"] < 1:
            bad.append(f"overload_sweep.{name}: no fault actually "
                       "injected")
    fu_slo = fu["slo_attainment"].get("interactive", 0.0)
    fg_slo = fg["slo_attainment"].get("interactive", 0.0)
    if fg_slo < fu_slo + _OVERLOAD_FAULT_MARGIN:
        bad.append(f"overload_sweep: under fault + overload the gateway "
                   f"held interactive attainment {fg_slo} vs ungated "
                   f"{fu_slo} — need a >= {_OVERLOAD_FAULT_MARGIN} "
                   "margin from capacity-aware admission")
    return bad


_SPEC_SERVE_KEYS = ("scenario", "speculative", "corruption", "served",
                    "offered", "runtime_errors", "total_tokens",
                    "spec_rounds", "spec_tokens", "acceptance_rate",
                    "spec_fallbacks", "tokens_per_s", "cost_usd",
                    "usd_per_token", "clone_seconds_by_type")


def _check_spec_serving(doc: dict) -> list:
    """``spec`` sweep violations in BENCH_serving.json (ISSUE 9)."""
    bad = []
    sweep = doc.get("spec")
    if not sweep:               # optional: --spec-requests 0 disables
        return bad
    for k in ("spec_k", "draft_cost", "draft_tier", "verify_tier", "rows"):
        if k not in sweep:
            return [f"spec: missing {k!r}{_regen(_REGEN_SERVING)}"]
    by = {}
    for i, row in enumerate(sweep["rows"]):
        missing = [k for k in _SPEC_SERVE_KEYS if k not in row]
        if missing:
            return bad + [f"spec.rows[{i}]: missing {missing}"
                          f"{_regen(_REGEN_SERVING)}"]
        by[row["scenario"]] = row
        if row["runtime_errors"] != 0:
            bad.append(f"spec.{row['scenario']}: raised — speculation "
                       "must degrade, never crash")
        if row["served"] != row["offered"]:
            bad.append(f"spec.{row['scenario']}: lost requests "
                       f"({row['served']}/{row['offered']})")
        if row["speculative"] and not row.get(
                "tokens_identical_to_pinned_large", False):
            bad.append(f"spec.{row['scenario']}: output diverged from "
                       "plain greedy decode — speculation must be "
                       "lossless")
    for k in ("pinned_large", "spec", "spec_corrupted"):
        if k not in by:
            return bad + [f"spec: missing scenario {k!r}"
                          f"{_regen(_REGEN_SERVING)}"]
    pinned, spec, corrupted = (by[k] for k in ("pinned_large", "spec",
                                               "spec_corrupted"))
    if spec["acceptance_rate"] < 1.0 - 1e-9:
        bad.append(f"spec.spec: oracle acceptance "
                   f"{spec['acceptance_rate']} < 1.0")
    if not 0.0 < corrupted["acceptance_rate"] < 1.0:
        bad.append(f"spec.spec_corrupted: acceptance "
                   f"{corrupted['acceptance_rate']} not in (0, 1) — the "
                   "sweep is not exercising partial acceptance")
    if spec["spec_rounds"] < 1 or spec["spec_tokens"] <= spec["spec_rounds"]:
        bad.append("spec.spec: no verify round amortized > 1 token")
    if spec["usd_per_token"] >= pinned["usd_per_token"]:
        bad.append(f"spec.spec: ${spec['usd_per_token']}/token not below "
                   f"pinned-large ${pinned['usd_per_token']}/token — "
                   "cross-tier drafting must cut serving cost")
    if spec["tokens_per_s"] < pinned["tokens_per_s"] - 1e-9:
        bad.append(f"spec.spec: {spec['tokens_per_s']} tokens/s below "
                   f"pinned-large {pinned['tokens_per_s']} — the cheaper "
                   "run must not lose throughput")
    return bad


_DISAGG_ROW_KEYS = ("scenario", "clone_type", "disagg", "compress",
                    "served", "offered", "runtime_errors", "total_tokens",
                    "p50_ttft_s", "p99_ttft_s", "cost_usd", "usd_per_token",
                    "disagg_handoffs", "kv_transfer_bytes", "kv_transfer_s",
                    "clone_seconds_by_type")
_AFFINITY_ROW_KEYS = ("scenario", "served", "offered", "runtime_errors",
                      "prefix_hit_rate", "per_clone")


def _check_disagg(doc: dict) -> list:
    """``disagg`` sweep violations in BENCH_serving.json (ISSUE 10)."""
    bad = []
    sweep = doc.get("disagg")
    if not sweep:               # optional: --disagg-requests 0 disables
        return bad
    for k in ("prompt_len", "new_tokens", "chunk", "decode_tier",
              "prefill_tier", "rows", "affinity"):
        if k not in sweep:
            return [f"disagg: missing {k!r}{_regen(_REGEN_SERVING)}"]
    by = {}
    for i, row in enumerate(sweep["rows"]):
        missing = [k for k in _DISAGG_ROW_KEYS if k not in row]
        if missing:
            return bad + [f"disagg.rows[{i}]: missing {missing}"
                          f"{_regen(_REGEN_SERVING)}"]
        by[row["scenario"]] = row
        if row["runtime_errors"] != 0:
            bad.append(f"disagg.{row['scenario']}: raised — the partner "
                       "path must degrade to co-located, never crash")
        if row["served"] != row["offered"]:
            bad.append(f"disagg.{row['scenario']}: lost requests "
                       f"({row['served']}/{row['offered']})")
        if row["disagg"] and row["disagg_handoffs"] < 1:
            bad.append(f"disagg.{row['scenario']}: zero handoffs — the "
                       "sweep is not exercising the partner prefill")
    for k in ("colocated_large", "disagg", "disagg_compressed"):
        if k not in by:
            return bad + [f"disagg: missing scenario {k!r}"
                          f"{_regen(_REGEN_SERVING)}"]
    coloc, plain, comp = (by[k] for k in ("colocated_large", "disagg",
                                          "disagg_compressed"))
    if not plain.get("tokens_identical_to_colocated_large", False):
        bad.append("disagg.disagg: output diverged from colocated decode "
                   "— an uncompressed KV handoff must be lossless")
    if comp["kv_transfer_bytes"] >= 0.5 * plain["kv_transfer_bytes"]:
        bad.append(f"disagg.disagg_compressed: {comp['kv_transfer_bytes']} "
                   f"wire bytes not < 0.5x the uncompressed "
                   f"{plain['kv_transfer_bytes']} — int8 KV quantization "
                   "is not actually shrinking the handoff")
    if comp["usd_per_token"] >= coloc["usd_per_token"]:
        bad.append(f"disagg.disagg_compressed: ${comp['usd_per_token']}"
                   f"/token not below colocated-large "
                   f"${coloc['usd_per_token']}/token — disaggregation "
                   "must cut serving cost")
    if comp["p99_ttft_s"] > coloc["p99_ttft_s"] + 1e-9:
        bad.append(f"disagg.disagg_compressed: p99 TTFT "
                   f"{comp['p99_ttft_s']} above colocated-large "
                   f"{coloc['p99_ttft_s']} — the cheaper run must not "
                   "lose first-token latency")
    aff = {}
    for i, row in enumerate(sweep["affinity"].get("rows", [])):
        missing = [k for k in _AFFINITY_ROW_KEYS if k not in row]
        if missing:
            return bad + [f"disagg.affinity.rows[{i}]: missing {missing}"
                          f"{_regen(_REGEN_SERVING)}"]
        aff[row["scenario"]] = row
        if row["runtime_errors"] != 0:
            bad.append(f"disagg.affinity.{row['scenario']}: raised")
        if row["served"] != row["offered"]:
            bad.append(f"disagg.affinity.{row['scenario']}: lost requests "
                       f"({row['served']}/{row['offered']})")
    for k in ("affinity", "random"):
        if k not in aff:
            return bad + [f"disagg.affinity: missing scenario {k!r}"
                          f"{_regen(_REGEN_SERVING)}"]
    if aff["affinity"]["prefix_hit_rate"] <= aff["random"][
            "prefix_hit_rate"]:
        bad.append(f"disagg.affinity: affinity hit rate "
                   f"{aff['affinity']['prefix_hit_rate']} not strictly "
                   f"above random {aff['random']['prefix_hit_rate']} — "
                   "prefix-affinity routing is not earning its keep")
    return bad


def check_serving(path: Path) -> list:
    """BENCH_serving.json violations (empty == pass)."""
    bad = []
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e}){_regen(_REGEN_SERVING)}"]
    for k in ("benchmark", "arch", "seed", "rows", "prefix_sweep",
              "tight_pool"):
        if k not in doc:
            bad.append(f"missing top-level key {k!r}"
                       f"{_regen(_REGEN_SERVING)}")
    if bad:
        return bad
    if doc["benchmark"] != "serving_load":
        bad.append(f"benchmark != serving_load: {doc['benchmark']!r}")
    if not doc["rows"]:
        bad.append("rows is empty")
    for i, row in enumerate(doc["rows"]):
        missing = [k for k in _SERVING_ROW_KEYS if k not in row]
        if missing:
            bad.append(f"rows[{i}]: missing {missing}")
    sweep = doc["prefix_sweep"]
    if sweep:                       # optional: --prefix-len 0 disables
        if len(sweep) != 2:
            return bad + [f"prefix_sweep must hold [baseline, shared]: "
                          f"{len(sweep)} rows"]
        for i, row in enumerate(sweep):
            missing = [k for k in _PREFIX_KEYS if k not in row]
            if missing:
                return bad + [f"prefix_sweep[{i}]: missing {missing}"]
        base, shared = sweep
        if base["prefix_cache"] or not shared["prefix_cache"]:
            bad.append("prefix_sweep rows must be [cache off, cache on]")
        if shared["prefix_hit_rate"] <= 0:
            bad.append("shared-prefix sweep shows no prefix hits — the "
                       "cache is not matching the common prompt")
        if base["prefix_hit_rate"] != 0:
            bad.append("un-shared baseline reported prefix hits")
        for name, row in (("baseline", base), ("shared", shared)):
            if row["served"] != row["offered"]:
                bad.append(f"prefix_sweep {name}: served {row['served']} "
                           f"!= offered {row['offered']}")
        if shared["p50_ttft_s"] > base["p50_ttft_s"] + 1e-9:
            bad.append(
                f"prefix sharing raised TTFT: {shared['p50_ttft_s']} vs "
                f"baseline {base['p50_ttft_s']} — the deterministic sweep "
                "must show admission getting cheaper, not dearer")
    tight = doc["tight_pool"]
    if tight:                       # optional: --tight-blocks 0 disables
        missing = [k for k in _TIGHT_KEYS if k not in tight]
        if missing:
            return bad + [f"tight_pool: missing {missing}"]
        if tight["served"] != tight["offered"]:
            bad.append(f"tight pool lost requests: {tight['served']}/"
                       f"{tight['offered']} — preemption must complete "
                       "every request")
        if tight["runtime_errors"] != 0:
            bad.append("tight pool hit RuntimeErrors — exhaustion must "
                       "preempt, never crash")
        if tight["preemptions"] <= 0:
            bad.append("tight pool never preempted — the sweep is not "
                       "actually exercising pool pressure")
    bad += _check_fleet(doc)
    bad += _check_mixed(doc)
    bad += _check_faults(doc)
    bad += _check_gateway(doc)
    bad += _check_spec_serving(doc)
    bad += _check_disagg(doc)
    return bad


def main(argv: list) -> int:
    bad = []
    if argv and argv[0] == "--serving":
        paths = [(Path(argv[1]) if len(argv) > 1 else DEFAULT_SERVING,
                  check_serving)]
    elif argv:
        paths = [(Path(argv[0]), check)]
    else:
        paths = [(DEFAULT, check), (DEFAULT_SERVING, check_serving)]
    for path, fn in paths:
        errs = fn(path)
        for b in errs:
            print(f"BENCH SCHEMA  {b}")
        print(f"checked {path.name}: {len(errs)} violations")
        bad += errs
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
