"""Step-versioned checkpointing: atomic npz + JSON manifest, async option.

Fault-tolerance contract (DESIGN.md §8): a training job killed at any point
restarts from the newest complete checkpoint; the write is atomic (tmp file +
rename) so a crash mid-save never corrupts the latest good state.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional, Tuple

import jax
import numpy as np


def _leaf_names(tree) -> list:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in paths]


def save(directory: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    names = _leaf_names(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    # np.savez stores extension dtypes (bfloat16) as raw void bytes; record
    # the true dtypes so restore can view-cast them back
    dtypes = [str(np.asarray(leaf).dtype) for leaf in leaves]
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path)
    manifest = {"step": step, "names": names, "n_leaves": len(leaves),
                "dtypes": dtypes}
    mpath = os.path.join(directory, f"ckpt_{step:08d}.json")
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f)
    os.replace(mpath + ".tmp", mpath)
    _prune(directory, keep)
    return path


def save_async(directory: str, step: int, tree,
               keep: int = 3) -> threading.Thread:
    """Snapshot to host memory synchronously, write to disk off-thread."""
    host_tree = jax.tree.map(np.asarray, tree)
    t = threading.Thread(target=save, args=(directory, step, host_tree, keep),
                         daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")
             and not f.endswith(".tmp.npz")]
    return max(steps) if steps else None


def restore(directory: str, example_tree, step: Optional[int] = None
            ) -> Tuple[int, object]:
    """Restore into the structure of ``example_tree`` (shapes validated)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    mpath = os.path.join(directory, f"ckpt_{step:08d}.json")
    dtypes = None
    if os.path.exists(mpath):
        with open(mpath) as f:
            dtypes = json.load(f).get("dtypes")
    leaves, treedef = jax.tree.flatten(example_tree)
    restored = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if dtypes and arr.dtype.kind == "V":       # bf16 etc: view-cast back
            arr = arr.view(jax.numpy.dtype(dtypes[i]))
        if hasattr(ref, "shape") and tuple(ref.shape) != arr.shape:
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != {ref.shape}")
        restored.append(arr)
    return step, jax.tree.unflatten(treedef, restored)


def _prune(directory: str, keep: int) -> None:
    steps = sorted(
        int(f[5:13]) for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
        and not f.endswith(".tmp.npz"))
    for s in steps[:-keep]:
        for ext in (".npz", ".json"):
            try:
                os.remove(os.path.join(directory, f"ckpt_{s:08d}{ext}"))
            except OSError:
                pass
