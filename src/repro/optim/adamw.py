"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX).

Optimizer state (mu, nu) is fp32 regardless of param dtype; the update is
computed in fp32 and cast back (standard bf16-params / fp32-state recipe —
10 bytes/param accounted in the roofline memory analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
    cosine = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cosine)


def init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: OptConfig, grads, opt_state: Dict, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        step_dir = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step_dir + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
