"""Energy estimation models.

Two models, per DESIGN.md §2:

1. ``PowerTutorModel`` — the paper's modified PowerTutor model (Table 2,
   HTC Dream), with the exact published coefficients.  Used by the
   reproduction benchmarks to produce the Figures 6-14 energy numbers and
   per-component breakdowns (Figures 8, 10).

2. ``TpuEnergyModel`` — the fleet adaptation: same independent-linear-
   component form (PowerTutor reports <=6.27% error for that assumption),
   with chip/HBM/link components instead of CPU/LCD/WiFi/3G.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


# --------------------------------------------------------------------------- #
# Paper model (Table 2) — coefficients in mW
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PowerTutorCoeffs:
    beta_uh: float = 4.32          # per % util at high freq
    beta_ul: float = 3.42          # per % util at low freq
    beta_cpu_on: float = 121.46
    beta_wifi_l: float = 20.0
    beta_wifi_h: float = 710.0
    beta_3g_idle: float = 10.0
    beta_3g_fach: float = 401.0    # CELL_SHARED
    beta_3g_dch: float = 570.0     # CELL_DEDICATED
    beta_br: float = 2.40          # per brightness unit (0-255)
    wifi_transmit: float = 1000.0  # transmit-burst power


@dataclasses.dataclass
class PhoneState:
    cpu_util: float = 0.0          # 0-100
    freq_high: bool = True
    cpu_on: bool = True
    brightness: int = 150
    wifi: str = "off"              # off | low | high | transmit
    cell: str = "off"              # off | idle | fach | dch


class PowerTutorModel:
    def __init__(self, coeffs: PowerTutorCoeffs = PowerTutorCoeffs()):
        self.c = coeffs

    def power_mw(self, st: PhoneState) -> Dict[str, float]:
        """Per-component power (mW) — the paper's independent-sum model."""
        c = self.c
        comps = {}
        if st.cpu_on:
            beta = c.beta_uh if st.freq_high else c.beta_ul
            comps["cpu"] = beta * st.cpu_util + c.beta_cpu_on
        else:
            comps["cpu"] = 0.0
        comps["screen"] = c.beta_br * st.brightness
        comps["wifi"] = {"off": 0.0, "low": c.beta_wifi_l,
                         "high": c.beta_wifi_h,
                         "transmit": c.wifi_transmit}[st.wifi]
        comps["3g"] = {"off": 0.0, "idle": c.beta_3g_idle,
                       "fach": c.beta_3g_fach, "dch": c.beta_3g_dch}[st.cell]
        return comps

    def energy_j(self, st: PhoneState, seconds: float) -> Dict[str, float]:
        return {k: v * 1e-3 * seconds for k, v in self.power_mw(st).items()}

    # -- scenario helpers used by the benchmarks ------------------------------
    def local_exec_energy(self, seconds: float) -> Dict[str, float]:
        """Phone computing at 100% util, screen on (paper §7.3 observation)."""
        return self.energy_j(PhoneState(cpu_util=100.0), seconds)

    def offload_energy(self, idle_seconds: float, tx_seconds: float,
                       link: str) -> Dict[str, float]:
        """Phone waiting (screen on, CPU lightly loaded) + radio transfer."""
        wait = PhoneState(cpu_util=5.0,
                          wifi="low" if link.startswith("wifi") else "off",
                          cell="idle" if link == "3g" else "off")
        e = self.energy_j(wait, idle_seconds)
        tx = PhoneState(cpu_util=10.0,
                        wifi="transmit" if link.startswith("wifi") else "off",
                        cell="dch" if link == "3g" else "off")
        for k, v in self.energy_j(tx, tx_seconds).items():
            e[k] = e.get(k, 0.0) + v
        return e


# --------------------------------------------------------------------------- #
# Fleet adaptation
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class TpuCoeffs:
    chip_idle_w: float = 70.0
    chip_peak_w: float = 250.0
    hbm_w_per_gbps: float = 0.05       # W per GB/s streamed
    ici_w_per_gbps: float = 0.04
    dcn_w_per_gbps: float = 0.08
    host_w: float = 350.0              # per-host static


class TpuEnergyModel:
    """Independent-component linear model for a TPU venue."""

    def __init__(self, coeffs: TpuCoeffs = TpuCoeffs()):
        self.c = coeffs

    def energy_j(self, *, chips: int, seconds: float, util: float,
                 hbm_bytes: float = 0.0, ici_bytes: float = 0.0,
                 dcn_bytes: float = 0.0, hosts: int = 1) -> Dict[str, float]:
        c = self.c
        chip_p = c.chip_idle_w + (c.chip_peak_w - c.chip_idle_w) * util
        return {
            "chips": chips * chip_p * seconds,
            "hbm": c.hbm_w_per_gbps * (hbm_bytes / 1e9),
            "ici": c.ici_w_per_gbps * (ici_bytes / 1e9),
            "dcn": c.dcn_w_per_gbps * (dcn_bytes / 1e9),
            "host": hosts * c.host_w * seconds,
        }

    def total_j(self, **kw) -> float:
        return sum(self.energy_j(**kw).values())

    def busy_j(self, chips: int, seconds: float, util: float = 1.0) -> float:
        """Chips-aware busy energy of one venue dispatch (ADR-004).

        The serving layer bills every task through this instead of the old
        flat ``venue_seconds x power_peak``, so a clone type's *chip count*
        scales its bill — an x8large tier burning 8 chips is no longer
        charged like a 1-chip ``basic`` clone."""
        return self.total_j(chips=chips, seconds=seconds, util=util)
