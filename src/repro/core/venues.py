"""Execution venues: the "phone" (local) and cloud TPU meshes (remote).

A venue is somewhere a remoteable method can run.  On this CPU-only container
every venue *executes* on the host; venue-relative wall-clock is obtained by
scaling one real host measurement by the venue's effective-throughput ratio
(DESIGN.md §2 "Simulation honesty": measured = host wall clock; modeled =
scaled).  On a real deployment ``host_speedup`` is 1.0 for the venue you are
on and execution is genuinely remote.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

# ---- hardware constants (TPU v5e, per chip) -------------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB
ICI_BW = 50e9                     # B/s per link
DCN_BW = 25e9                     # B/s per host NIC (inter-pod)

# ---- scenario link profiles (paper §7: Phone / WiFi-Local / WiFi-Internet /
# 3G), with their 2026 fleet analogues --------------------------------------
@dataclasses.dataclass(frozen=True)
class LinkProfile:
    name: str
    bandwidth: float              # bytes/s
    rtt: float                    # seconds

LINKS = {
    # paper-era client links (used by the reproduction benchmarks)
    "wifi-local": LinkProfile("wifi-local", 6.75e6, 0.005),     # 54 Mbit
    "wifi-internet": LinkProfile("wifi-internet", 2.5e6, 0.050),
    "wifi-hotspot": LinkProfile("wifi-hotspot", 2.5e6, 0.200),
    "3g": LinkProfile("3g", 0.25e6, 0.100),
    # fleet links (used by the serving/training layer)
    "ici": LinkProfile("ici", ICI_BW, 1e-6),
    "dcn": LinkProfile("dcn", DCN_BW, 50e-6),
}


@dataclasses.dataclass
class VenueSpec:
    """Static description of a compute venue."""

    name: str
    chips: int = 1
    eff_flops: float = 1e9        # sustained useful FLOP/s for our workloads
    hbm_bytes: int = HBM_BYTES
    mem_bytes: int = HBM_BYTES    # method working-set budget (OOM escalation)
    power_idle: float = 60.0      # W
    power_peak: float = 200.0     # W at full utilization
    link: LinkProfile = LINKS["wifi-local"]


_HOST_EFF_FLOPS: Optional[float] = None


def host_eff_flops(refresh: bool = False) -> float:
    """Calibrate this host's sustained f32 matmul throughput (measured once)."""
    global _HOST_EFF_FLOPS
    if _HOST_EFF_FLOPS is not None and not refresh:
        return _HOST_EFF_FLOPS
    n = 512
    import jax.numpy as jnp
    x = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(x).block_until_ready()
    t0 = time.perf_counter()
    reps = 8
    for _ in range(reps):
        x = f(x)
    x.block_until_ready()
    dt = max(time.perf_counter() - t0, 1e-9)
    _HOST_EFF_FLOPS = 2 * n ** 3 * reps / dt
    return _HOST_EFF_FLOPS


# ---- venue catalogue --------------------------------------------------------
# "phone": a 2011-era handset (paper's HTC Dream).  The cloud VM types mirror
# the paper's Table 1; TPU venues are the fleet adaptation.
def make_phone() -> VenueSpec:
    return VenueSpec("phone", chips=1, eff_flops=0.05e9,
                     mem_bytes=16 * 2 ** 20,       # 16 MB Dalvik heap cap
                     power_idle=0.0, power_peak=0.0,  # phone energy uses
                     link=LINKS["wifi-local"])        # the PowerTutor model


def make_cloud_vm(name: str, cpus: int, mem_mb: int, heap_mb: int,
                  link: LinkProfile) -> VenueSpec:
    return VenueSpec(name, chips=cpus, eff_flops=1.5e9 * cpus,
                     mem_bytes=heap_mb * 2 ** 20,
                     hbm_bytes=mem_mb * 2 ** 20,
                     power_idle=10.0 * cpus, power_peak=35.0 * cpus,
                     link=link)


def make_tpu_venue(name: str, chips: int, link: LinkProfile,
                   mfu: float = 0.4) -> VenueSpec:
    return VenueSpec(name, chips=chips,
                     eff_flops=PEAK_FLOPS_BF16 * mfu * chips,
                     hbm_bytes=HBM_BYTES * chips,
                     mem_bytes=HBM_BYTES * chips,
                     power_idle=70.0 * chips, power_peak=250.0 * chips,
                     link=link)


class Venue:
    """A live venue: executes jitted callables and reports venue-time.

    ``execute`` returns (result, venue_seconds).  venue_seconds = measured
    host wall clock x (host_eff / venue_eff) — the simulation-honesty rule.
    """

    def __init__(self, spec: VenueSpec, clock: Callable[[], float] = None):
        self.spec = spec
        self.clock = clock or time.perf_counter
        self.healthy = True

    def speed_ratio(self) -> float:
        return host_eff_flops() / self.spec.eff_flops

    def execute(self, fn: Callable, *args, warm: bool = True, **kwargs):
        """Run fn; returns (result, venue_seconds).

        ``warm=True`` runs once first so XLA compilation (the clone *boot*
        cost, accounted separately by the ClonePool) doesn't pollute the
        steady-state execution measurement.
        """
        if warm:
            jax.block_until_ready(fn(*args, **kwargs))
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        out = jax.block_until_ready(out)
        host_dt = time.perf_counter() - t0
        if host_dt < 1.0:
            # cheap call: retime once and keep the min, so a transient host
            # stall can't inflate the venue model (single samples under a
            # loaded host flake the parallel speedup accounting)
            t1 = time.perf_counter()
            out = jax.block_until_ready(fn(*args, **kwargs))
            host_dt = min(host_dt, time.perf_counter() - t1)
        return out, host_dt * self.speed_ratio()

    def estimate_time(self, flops: float) -> float:
        return flops / self.spec.eff_flops

    def fits(self, workset_bytes: int) -> bool:
        return workset_bytes <= self.spec.mem_bytes


def transfer_time(nbytes: int, link: LinkProfile) -> float:
    return link.rtt + nbytes / link.bandwidth


def pytree_bytes(tree) -> int:
    """Serialized payload size of a pytree (leaf bytes + small per-leaf tax).

    Dtype-honest: abstract leaves carrying only (shape, dtype) — e.g.
    ``jax.ShapeDtypeStruct`` from ``abstract_cache`` — are billed at
    ``prod(shape) * dtype.itemsize``, so an int8 KV block costs one byte
    per element rather than whatever width ``np.asarray`` coerces to.
    """
    leaves = jax.tree.leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += (int(np.prod(leaf.shape, dtype=np.int64))
                      * np.dtype(leaf.dtype).itemsize)
        else:
            total += len(np.asarray(leaf).tobytes())
    return total + 64 * max(len(leaves), 1)   # framing/metadata overhead


def kv_block_bytes(config, block_size: int, *, quantized: bool = False) -> int:
    """Modeled wire size of one paged KV block for ``config``.

    A block holds ``block_size`` tokens of K and V for every attention
    layer: ``block_size * n_attn_layers * 2 * n_kv_heads * head_dim``
    elements at the model dtype.  ``quantized=True`` bills the int8
    transfer stream instead: 1 byte/element plus one float32 scale per
    (layer, K/V, head, block) — the per-head scales the compressed
    migration path ships alongside the int8 payload.
    """
    n_attn = sum(1 for k in config.layer_kinds() if k == "attn")
    per_tok = n_attn * 2 * config.n_kv_heads * config.head_dim
    if quantized:
        scales = n_attn * 2 * config.n_kv_heads * 4
        return block_size * per_tok + scales
    import jax.numpy as jnp
    itemsize = jnp.dtype(config.dtype).itemsize
    return block_size * per_tok * itemsize
