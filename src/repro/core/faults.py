"""Fault injection + fallback semantics (paper §4.4).

"If the connection fails for any reason during remote execution, the
framework falls back to local execution, discarding any data collected by
the profiler [for that run].  At the same time, the Execution Controller
initiates asynchronous reconnection to the server."

Two layers live here (ADR-006):

- :class:`FaultPlan` / :class:`ReconnectManager`: the seed's per-execution
  fault check and reconnect backoff used by the ``ExecutionController``'s
  offload path.  The manager's backoff now also runs as events on a
  :class:`~repro.core.clock.VirtualClock` (pass ``clock=``) so reconnect
  attempts land deterministically on the simulated timeline; the original
  synchronous and threaded modes are preserved for clock-less callers.
- :class:`CloneFault` / :class:`FaultInjector`: clock-driven per-clone
  failure and slowdown schedules for the serving stack.  A fired kill or
  drain marks the clone DEAD, trips its circuit breaker (which then
  probes itself back half-open → closed on the same clock), and parks the
  clone on ``injector.failed`` for the serving handler to recover its
  in-flight requests (KV migration or prefix-accelerated restore).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Callable, List, Optional, Tuple

from repro.core.clock import ensure_clock
from repro.core.clones import Clone, CloneHealth, ClonePool, CloneState


class VenueFailure(RuntimeError):
    """Raised when a remote venue dies mid-execution."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault schedule for tests/benchmarks."""
    fail_next: int = 0                 # fail the next N remote executions
    fail_every: Optional[int] = None   # or every k-th execution
    _count: int = 0

    def check(self) -> bool:
        """True -> this remote execution should fail."""
        self._count += 1
        if self.fail_next > 0:
            self.fail_next -= 1
            return True
        if self.fail_every and self._count % self.fail_every == 0:
            return True
        return False


class ReconnectManager:
    """Asynchronous reconnect with capped exponential backoff.

    Three execution modes, chosen at construction:

    - ``clock=``: attempts are :class:`VirtualClock` events — the first
      fires ``base_delay`` after the failure, each retry doubles the
      delay up to ``max_delay``, at most ``max_attempts`` per failure
      burst.  Fully deterministic on the simulated timeline.
    - ``synchronous=True`` (default, no clock): the whole backoff loop
      runs inline with no sleeping — the seed's deterministic test mode.
    - ``synchronous=False`` (no clock): a daemon thread with real
      ``time.sleep`` between attempts (the paper's live mode).
    """

    def __init__(self, reconnect_fn: Optional[Callable[[], bool]] = None,
                 base_delay: float = 0.05, max_delay: float = 2.0,
                 max_attempts: int = 8, synchronous: bool = True,
                 clock=None):
        self.reconnect_fn = reconnect_fn or (lambda: True)
        self.base_delay = base_delay
        self.max_delay = max_delay
        self.max_attempts = max_attempts
        self.synchronous = synchronous
        self.clock = None if clock is None else ensure_clock(clock)
        if self.clock is not None and not getattr(self.clock, "virtual",
                                                  False):
            raise TypeError("ReconnectManager backoff events need a "
                            "VirtualClock; omit clock for wall-clock use")
        self.connected = True
        self.attempts = 0                 # lifetime attempt count
        self._burst = 0                   # attempts since last failure
        self._event = None                # pending clock event
        self._thread: Optional[threading.Thread] = None

    def notify_failure(self) -> None:
        self.connected = False
        if self.clock is not None:
            if self._event is None or self._event.fired \
                    or self._event.cancelled:
                self._burst = 0
                self._schedule(self.base_delay)
        elif self.synchronous:
            self._run()                      # deterministic under test
        elif self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

    # ------------------------------------------------------- clock-mode
    def _schedule(self, delay: float) -> None:
        self._event = self.clock.schedule(
            delay, functools.partial(self._attempt, delay))

    def _attempt(self, delay: float) -> None:
        self.attempts += 1
        self._burst += 1
        if self.reconnect_fn():
            self.connected = True
            return
        if self._burst < self.max_attempts:
            self._schedule(min(delay * 2, self.max_delay))

    # -------------------------------------------------- wall-clock mode
    def _run(self) -> None:
        import time
        delay = self.base_delay
        for i in range(self.max_attempts):
            self.attempts += 1
            if self.reconnect_fn():
                self.connected = True
                return
            if not self.synchronous:
                time.sleep(delay)
            delay = min(delay * 2, self.max_delay)


FAULT_KINDS = ("kill", "drain", "slow")


@dataclasses.dataclass
class CloneFault:
    """One scheduled fault on the virtual timeline (ADR-006).

    ``kind="kill"``: abrupt fail-stop — the clone's memory (KV pool
    included) is lost; in-flight requests can only be restored by
    re-prefill.  ``kind="drain"``: graceful failure with notice (a
    preemption warning / NIC-level drop with the VM still up): the
    clone stops serving but its KV blocks stay salvageable, so the
    handler may migrate them to a survivor.  ``kind="slow"``: the clone
    degrades by ``factor`` for ``duration`` seconds — hedged dispatch's
    target.  ``cid=None`` targets the lowest-cid busy healthy running
    secondary at fire time (deterministic); for kill/drain a positive
    ``duration`` schedules the clone's recovery (health SUSPECT, then a
    breaker probe closes the loop), ``0`` is permanent.
    """

    at: float
    kind: str = "kill"
    cid: Optional[int] = None
    duration: float = 0.0
    factor: float = 4.0


class FaultInjector:
    """Clock-driven per-clone failure/slowdown schedules for a pool.

    ``arm()`` turns every :class:`CloneFault` into a VirtualClock event.
    Firing a kill/drain marks the target DEAD, powers it off (memory and
    executable cache gone), trips its breaker — binding the breaker's
    half-open probe chain to the same clock — and appends ``(clone,
    fault)`` to :attr:`failed` for the serving handler's recovery pass.
    Slowdowns scale the clone's dispatched venue seconds until their
    window elapses.  A fault whose target cannot be resolved (no busy
    healthy clone, or the named cid is not running) counts as a miss.
    """

    def __init__(self, pool: ClonePool, faults: List[CloneFault],
                 clock=None, on_fire=None):
        for f in faults:
            if f.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {f.kind!r}; "
                                 f"expected one of {FAULT_KINDS}")
        self.pool = pool
        #: optional ``(clone, fault) -> None`` callback invoked at the
        #: instant a kill/drain lands — capacity-loss signal for
        #: admission control (the gateway tightens before the serving
        #: loop's next fleet census)
        self.on_fire = on_fire
        self.clock = pool.clock if clock is None else ensure_clock(clock)
        if not getattr(self.clock, "virtual", False):
            raise TypeError("FaultInjector schedules need a VirtualClock")
        self.faults = sorted(faults, key=lambda f: f.at)
        self.stats = {"injected": 0, "kills": 0, "drains": 0,
                      "slowdowns": 0, "misses": 0, "clone_recoveries": 0}
        self.failed: List[Tuple[Clone, CloneFault]] = []
        self._armed = False
        self._events: List[tuple] = []     # (fault, Event)

    # ----------------------------------------------------------- schedule
    def arm(self) -> None:
        """Schedule every fault; idempotent."""
        if self._armed:
            return
        self._armed = True
        for f in self.faults:
            ev = self.clock.at(max(f.at, self.clock.now()),
                               functools.partial(self._fire, f))
            self._events.append((f, ev))

    def next_event_time(self) -> Optional[float]:
        """Earliest unfired fault time — the serving loop bounds its
        waits on this so a mid-window death is detected when it happens,
        not when the doomed dispatch would have completed."""
        times = [ev.time for _, ev in self._events
                 if not ev.fired and not ev.cancelled]
        return min(times) if times else None

    def drain_failed(self) -> List[Tuple[Clone, CloneFault]]:
        out, self.failed = self.failed, []
        return out

    # --------------------------------------------------------------- fire
    def _target(self, f: CloneFault) -> Optional[Clone]:
        if f.cid is not None:
            for c in self.pool.clones:
                if (c.cid == f.cid and c.state is CloneState.RUNNING
                        and c.health is CloneHealth.HEALTHY):
                    return c
            return None
        cands = [c for c in self.pool.clones
                 if not c.is_primary and c.state is CloneState.RUNNING
                 and c.health is CloneHealth.HEALTHY]
        busy = [c for c in cands if c.busy]
        pick = busy or cands
        return min(pick, key=lambda c: c.cid) if pick else None

    def _fire(self, f: CloneFault) -> None:
        now = self.clock.now()
        clone = self._target(f)
        if clone is None:
            self.stats["misses"] += 1
            return
        self.stats["injected"] += 1
        if f.kind == "slow":
            clone.slowdown = max(1.0, f.factor)
            self.stats["slowdowns"] += 1
            if f.duration > 0:
                self.clock.schedule(
                    f.duration, functools.partial(self._clear_slow, clone))
            return
        self.stats["kills" if f.kind == "kill" else "drains"] += 1
        clone.health = CloneHealth.DEAD
        clone.slowdown = 1.0
        clone.breaker.bind(self.clock,
                           functools.partial(self._probe, clone))
        clone.breaker.trip(now)
        if not clone.is_primary:
            # memory + executable cache die with the clone; the primary
            # is standing capacity — it stays billed but health-gated
            self.pool.power_off(clone)
        self.failed.append((clone, f))
        if self.on_fire is not None:
            self.on_fire(clone, f)
        if f.duration > 0:
            self.clock.schedule(f.duration,
                                functools.partial(self._revive, clone))

    def _clear_slow(self, clone: Clone) -> None:
        clone.slowdown = 1.0

    def _revive(self, clone: Clone) -> None:
        """The fault window elapsed: the clone answers pings again, but
        serves only after its breaker's probe promotes it (ADR-006)."""
        if clone.health is CloneHealth.DEAD:
            clone.health = CloneHealth.SUSPECT

    def _probe(self, clone: Clone) -> bool:
        """Breaker half-open probe: a dead clone fails it; a suspect one
        passes and returns to the placement-eligible set."""
        if clone.health is CloneHealth.DEAD:
            return False
        clone.health = CloneHealth.HEALTHY
        self.stats["clone_recoveries"] += 1
        return True
