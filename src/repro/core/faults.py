"""Fault injection + fallback semantics (paper §4.4).

"If the connection fails for any reason during remote execution, the
framework falls back to local execution, discarding any data collected by
the profiler [for that run]. At the same time, the Execution Controller
initiates asynchronous reconnection to the server."
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional


class VenueFailure(RuntimeError):
    """Raised when a remote venue dies mid-execution."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault schedule for tests/benchmarks."""
    fail_next: int = 0                 # fail the next N remote executions
    fail_every: Optional[int] = None   # or every k-th execution
    _count: int = 0

    def check(self) -> bool:
        """True -> this remote execution should fail."""
        self._count += 1
        if self.fail_next > 0:
            self.fail_next -= 1
            return True
        if self.fail_every and self._count % self.fail_every == 0:
            return True
        return False


class ReconnectManager:
    """Asynchronous reconnect with capped exponential backoff."""

    def __init__(self, reconnect_fn: Optional[Callable[[], bool]] = None,
                 base_delay: float = 0.05, max_delay: float = 2.0,
                 max_attempts: int = 8, synchronous: bool = True):
        self.reconnect_fn = reconnect_fn or (lambda: True)
        self.base_delay = base_delay
        self.max_delay = max_delay
        self.max_attempts = max_attempts
        self.synchronous = synchronous
        self.connected = True
        self.attempts = 0
        self._thread: Optional[threading.Thread] = None

    def notify_failure(self) -> None:
        self.connected = False
        if self.synchronous:
            self._run()                      # deterministic under test
        elif self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._run, daemon=True)
            self._thread.start()

    def _run(self) -> None:
        import time
        delay = self.base_delay
        for i in range(self.max_attempts):
            self.attempts += 1
            if self.reconnect_fn():
                self.connected = True
                return
            if not self.synchronous:
                time.sleep(delay)
            delay = min(delay * 2, self.max_delay)
