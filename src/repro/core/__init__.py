"""ThinkAir core: profile-driven computation offloading for JAX workloads."""
from repro.core.clock import (BaseClock, Event, FunctionClock, SystemClock,
                              VirtualClock, ensure_clock)
from repro.core.clones import (CLONE_TYPES, KV_SCALE_BY_CLONE_TYPE,
                               TPU_BY_CLONE_TYPE, TPU_CLONE_TYPES,
                               CircuitBreaker, Clone, CloneHealth,
                               ClonePool, CloneState, chips_for, resume_time,
                               usd_per_second)
from repro.core.controller import ExecutionController, ExecutionResult
from repro.core.dispatch import CloneTask, Dispatcher
from repro.core.energy import (PhoneState, PowerTutorModel, TpuCoeffs,
                               TpuEnergyModel)
from repro.core.faults import (CloneFault, FaultInjector, FaultPlan,
                               ReconnectManager, VenueFailure)
from repro.core.gateway import (AdmissionEstimator, ResponseCache,
                                StreamingGateway, TenantPolicy, TokenBucket)
from repro.core.parallel import (ParallelResult, Parallelizer, split_batch,
                                 split_range)
from repro.core.policy import (Policy, Prediction, placement_key,
                               should_offload)
from repro.core.profilers import (DeviceProfiler, NetworkProfiler,
                                  ProgramProfiler, size_bucket)
from repro.core.remoteable import (REGISTRY, RemoteableMethod, remote,
                                   set_default_controller)
from repro.core.scheduler import (AdmissionQueue, FleetAutoscaler,
                                  PlacementEngine, ServeCompletion,
                                  ServeRequest, poisson_arrivals)
from repro.core.venues import (LINKS, Venue, VenueSpec, pytree_bytes,
                               transfer_time)

__all__ = [
    "BaseClock", "Event", "FunctionClock", "SystemClock", "VirtualClock",
    "ensure_clock",
    "CLONE_TYPES", "KV_SCALE_BY_CLONE_TYPE", "TPU_BY_CLONE_TYPE",
    "TPU_CLONE_TYPES", "CircuitBreaker", "Clone", "CloneHealth",
    "ClonePool", "CloneState", "chips_for",
    "resume_time", "usd_per_second",
    "ExecutionController", "ExecutionResult", "CloneTask", "Dispatcher",
    "PhoneState", "PowerTutorModel", "TpuCoeffs", "TpuEnergyModel",
    "CloneFault", "FaultInjector",
    "FaultPlan", "ReconnectManager", "VenueFailure",
    "AdmissionEstimator", "ResponseCache", "StreamingGateway",
    "TenantPolicy", "TokenBucket", "ParallelResult",
    "Parallelizer", "split_batch", "split_range", "Policy", "Prediction",
    "placement_key", "should_offload",
    "DeviceProfiler", "NetworkProfiler", "ProgramProfiler",
    "size_bucket", "REGISTRY", "RemoteableMethod", "remote",
    "set_default_controller", "AdmissionQueue", "FleetAutoscaler",
    "PlacementEngine", "ServeCompletion", "ServeRequest", "poisson_arrivals",
    "LINKS", "Venue", "VenueSpec", "pytree_bytes", "transfer_time",
]
