"""The system timeline: one injected clock for every latency in the fleet.

The paper's Client Handler multiplexes many phone clients onto an elastic
clone pool; every cost it reasons about (resume, boot, transfer, execution,
idle TTLs) is a *duration on one timeline*.  The seed code mixed
``time.monotonic()`` stamps with returned-cost arithmetic, which made
overlap (k clones running in parallel) impossible to express and idle
reaping dependent on real wall clock.

This module provides that single timeline:

``VirtualClock``
    A deterministic discrete-event clock.  ``schedule(delay, cb)`` enqueues
    an event; ``advance_to(t)`` / ``sleep(dt)`` move time forward, firing
    events in timestamp order as they are crossed.  All simulated latency in
    the repo flows through one of these — there are *no real sleeps* on the
    simulated path.

``SystemClock`` / ``FunctionClock``
    Adapters so existing callers (real wall clock, or the tests'
    ``lambda: t[0]`` fakes) satisfy the same interface.  Their ``sleep`` is
    a no-op: modeled costs never block the host.

Every clock is callable (``clock()`` == ``clock.now()``) for backward
compatibility with the seed's ``Callable[[], float]`` convention.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, List, Optional, Tuple


class BaseClock:
    """Minimal clock interface: ``now()``, ``sleep(dt)``, callable."""

    #: True when time is simulated and events can be scheduled on it.
    virtual = False

    def now(self) -> float:
        """Current time in seconds on this clock's timeline."""
        raise NotImplementedError

    def sleep(self, dt: float) -> None:
        """Charge ``dt`` seconds to the timeline (no-op on real clocks:
        modeled costs must never block the host)."""

    def __call__(self) -> float:
        return self.now()


class SystemClock(BaseClock):
    """Real wall clock (``time.monotonic``); sleep is a no-op."""

    def now(self) -> float:
        return time.monotonic()


class FunctionClock(BaseClock):
    """Wraps a bare ``Callable[[], float]`` (the seed/test convention)."""

    def __init__(self, fn: Callable[[], float]):
        self.fn = fn

    def now(self) -> float:
        return float(self.fn())


class Event:
    """A scheduled occurrence on a :class:`VirtualClock`."""

    __slots__ = ("time", "seq", "callback", "cancelled", "fired")

    def __init__(self, t: float, seq: int, callback: Optional[Callable]):
        self.time = t
        self.seq = seq
        self.callback = callback
        self.cancelled = False
        self.fired = False

    def cancel(self) -> None:
        """Mark the event dead; it will be skipped (and pruned) unfired."""
        self.cancelled = True


class VirtualClock(BaseClock):
    """Deterministic event-queue clock.

    Invariants:
      - time never moves backwards;
      - events fire in (time, insertion) order, with ``now`` set to the
        event's timestamp while its callback runs;
      - callbacks may schedule further events (at or after the current
        time) but must not re-enter ``advance_to`` (single timeline).
    """

    virtual = True

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self._advancing = False

    # ------------------------------------------------------------- reading
    def now(self) -> float:
        """Current simulated time (seconds since ``start``)."""
        return self._now

    def pending(self) -> int:
        """Number of scheduled, not-yet-fired, not-cancelled events."""
        return sum(1 for _, _, e in self._heap if not e.cancelled)

    def next_event_time(self) -> Optional[float]:
        """Timestamp of the earliest live event, or None when idle."""
        self._prune()
        return self._heap[0][0] if self._heap else None

    # ---------------------------------------------------------- scheduling
    def schedule(self, delay: float, callback: Optional[Callable] = None
                 ) -> Event:
        """Enqueue an event ``delay`` seconds from now (>= 0)."""
        return self.at(self._now + max(0.0, float(delay)), callback)

    def at(self, t: float, callback: Optional[Callable] = None) -> Event:
        """Enqueue an event at absolute time ``t`` (>= now)."""
        if t < self._now - 1e-12:
            raise ValueError(f"cannot schedule in the past: {t} < {self._now}")
        ev = Event(max(t, self._now), next(self._seq), callback)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def _prune(self) -> None:
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)

    # ----------------------------------------------------------- advancing
    def advance_to(self, t: float) -> None:
        """Move time forward to ``t``, firing every due event in order."""
        if t < self._now - 1e-12:
            raise ValueError(f"time cannot run backwards: {t} < {self._now}")
        if self._advancing:
            raise RuntimeError("re-entrant VirtualClock.advance_to")
        self._advancing = True
        try:
            while True:
                self._prune()
                if not self._heap or self._heap[0][0] > t:
                    break
                _, _, ev = heapq.heappop(self._heap)
                self._now = max(self._now, ev.time)
                ev.fired = True
                if ev.callback is not None:
                    ev.callback()
            self._now = max(self._now, t)
        finally:
            self._advancing = False

    def advance(self, dt: float) -> None:
        self.advance_to(self._now + max(0.0, float(dt)))

    def sleep(self, dt: float) -> None:
        """Simulated sleep: advances the timeline (fires crossed events)."""
        self.advance(dt)

    def run_next(self) -> bool:
        """Advance to the next pending event; False when queue is empty."""
        t = self.next_event_time()
        if t is None:
            return False
        self.advance_to(t)
        return True

    def run_until_idle(self, max_events: int = 1_000_000) -> None:
        for _ in range(max_events):
            if not self.run_next():
                return
        raise RuntimeError("VirtualClock.run_until_idle: event storm")


def ensure_clock(clock) -> BaseClock:
    """Coerce None / bare callables / clocks into the clock interface.

    ``None`` yields a fresh :class:`VirtualClock` — the deterministic
    default for every simulated component.
    """
    if clock is None:
        return VirtualClock()
    if isinstance(clock, BaseClock):
        return clock
    if callable(clock):
        return FunctionClock(clock)
    raise TypeError(f"not a clock: {clock!r}")
