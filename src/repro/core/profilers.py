"""The three ThinkAir profilers (paper §6): device, program, network.

Intent-listener-style updates are modeled as explicit ``observe_*`` hooks;
everything keeps EMA histories exactly as the Execution Controller needs for
its decisions (paper §4.3: first encounter -> environment only; afterwards ->
history + environment).
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, Optional, Tuple

_EMA = 0.35


def _ema(old: Optional[float], new: float, alpha: float = _EMA) -> float:
    return new if old is None else (1 - alpha) * old + alpha * new


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class DeviceStatus:
    battery_level: float = 1.0
    cpu_load: float = 0.0
    connectivity: str = "wifi"     # wifi | cell | none
    conn_subtype: str = "wifi-local"


class DeviceProfiler:
    """Tracks environmental device state (paper §6.1, intent-based)."""

    def __init__(self):
        self.status = DeviceStatus()

    def observe(self, **kw) -> None:
        for k, v in kw.items():
            setattr(self.status, k, v)

    def connection_quality(self) -> str:
        """Coarse env signal used for first-encounter decisions (§4.3)."""
        if self.status.connectivity == "none":
            return "none"
        if self.status.conn_subtype in ("wifi-local", "wifi-internet", "ici",
                                        "dcn"):
            return "good"
        return "poor"


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class MethodRecord:
    """History for one (method, input-size bucket, venue)."""
    exec_time: Optional[float] = None     # EMA seconds
    energy: Optional[float] = None        # EMA joules (client-side)
    tx_bytes: Optional[float] = None
    rx_bytes: Optional[float] = None
    invocations: int = 0
    flops: Optional[float] = None         # from cost_analysis when available


def size_bucket(n: float) -> int:
    """Log-scale input-size bucketing so history generalizes across inputs."""
    if n <= 0:
        return 0
    return int(round(math.log2(max(n, 1)) * 2))


class ProgramProfiler:
    """Per-method execution history (paper §6.2)."""

    def __init__(self):
        self.records: Dict[Tuple[str, int, str], MethodRecord] = \
            defaultdict(MethodRecord)

    def record(self, method: str, size_key: int, venue: str, *,
               exec_time: float, energy: float = 0.0, tx: float = 0.0,
               rx: float = 0.0, flops: Optional[float] = None) -> None:
        r = self.records[(method, size_key, venue)]
        r.exec_time = _ema(r.exec_time, exec_time)
        r.energy = _ema(r.energy, energy)
        r.tx_bytes = _ema(r.tx_bytes, tx)
        r.rx_bytes = _ema(r.rx_bytes, rx)
        if flops is not None:
            r.flops = flops
        r.invocations += 1

    def lookup(self, method: str, size_key: int,
               venue: str) -> Optional[MethodRecord]:
        r = self.records.get((method, size_key, venue))
        return r if r and r.invocations > 0 else None

    def nearest(self, method: str, size_key: int,
                venue: str) -> Optional[MethodRecord]:
        """Closest size bucket with history (enables BIV interpolation)."""
        best, best_d = None, None
        for (m, s, v), r in self.records.items():
            if m != method or v != venue or r.invocations == 0:
                continue
            d = abs(s - size_key)
            if best_d is None or d < best_d:
                best, best_d = r, d
        return best

    def known(self, method: str) -> bool:
        return any(m == method and r.invocations > 0
                   for (m, _, _), r in self.records.items())


# --------------------------------------------------------------------------- #
class NetworkProfiler:
    """Perceived bandwidth / RTT per link (paper §6.3).

    Combines "intents" (profile switches) with instrumentation (observed
    transfer timings update the perceived bandwidth EMA, which includes
    serialization overhead exactly as the paper prescribes).
    """

    def __init__(self, link_name: str = "wifi-local"):
        from repro.core.venues import LINKS
        self._links = dict(LINKS)
        self.active = link_name
        self.perceived_bw: Dict[str, float] = {}
        self.perceived_rtt: Dict[str, float] = {}
        self.tx_packets = 0
        self.rx_packets = 0

    def switch(self, link_name: str) -> None:     # "intent" hook
        self.active = link_name
        # paper: network state change triggers RTT re-estimation
        self.perceived_rtt.pop(link_name, None)

    def observe_transfer(self, nbytes: int, seconds: float) -> None:
        if seconds <= 0:
            return
        bw = nbytes / seconds
        self.perceived_bw[self.active] = _ema(
            self.perceived_bw.get(self.active), bw)
        self.tx_packets += max(1, nbytes // 1400)

    def observe_rtt(self, seconds: float) -> None:  # app-level ping (§5.1)
        self.perceived_rtt[self.active] = _ema(
            self.perceived_rtt.get(self.active), seconds)

    def bandwidth(self) -> float:
        return self.perceived_bw.get(self.active,
                                     self._links[self.active].bandwidth)

    def rtt(self) -> float:
        return self.perceived_rtt.get(self.active,
                                      self._links[self.active].rtt)

    def transfer_time(self, nbytes: int) -> float:
        return self.rtt() + nbytes / self.bandwidth()
