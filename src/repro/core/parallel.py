"""Parallelization across clones (paper §7.4) + straggler mitigation.

The primary clone acts as a transparent proxy for k secondaries.  Since the
event-driven refactor, shards are *submitted* onto clones through the
:class:`~repro.core.dispatch.Dispatcher` and their completions are events
on the shared :class:`~repro.core.clock.VirtualClock` — k shards genuinely
overlap, so the parallel makespan observed on the timeline is
    resume(k) + max_i(shard_i) + sync(k)
exactly mirroring the paper's accounting ("the resume time is included in
the overhead time, which in turn is included in the execution time").

Straggler mitigation (fleet requirement, DESIGN.md §8) is now detected *at
event time*: once half the shards have completed, a deadline of
``straggler_factor x median(completed)`` is placed on the timeline; any
shard still pending when the deadline fires is re-dispatched to a spare
clone, and its effective completion is the earlier of (original, rescue).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.clock import VirtualClock, ensure_clock
from repro.core.clones import ClonePool, resume_time
from repro.core.dispatch import Dispatcher

# Per-secondary synchronization cost charged by the primary proxy (paper:
# "incurring extra synchronization overheads"; calibrated so that 8-queens
# gains flatten past ~4 clones as in Fig. 12).
SYNC_SECONDS_PER_CLONE = 0.050


@dataclasses.dataclass
class ParallelResult:
    value: object
    makespan_s: float              # resume + max shard + sync + merge
    shard_times: List[float]
    resume_s: float
    sync_s: float
    redispatches: int
    n_clones: int


def split_batch(args: tuple, k: int, axis: int = 0) -> List[tuple]:
    """Default splitter: split every array leaf's leading axis into k parts."""
    import jax

    def split_leaf(leaf):
        return np.array_split(np.asarray(leaf), k, axis=axis)

    leaves, treedef = jax.tree.flatten(args)
    parts = [split_leaf(leaf) for leaf in leaves]
    return [jax.tree.unflatten(treedef, [p[i] for p in parts])
            for i in range(k)]


def split_range(lo: int, hi: int, k: int) -> List[tuple]:
    """Range splitter (paper: N-queens board regions)."""
    edges = np.linspace(lo, hi, k + 1).astype(int)
    return [(int(edges[i]), int(edges[i + 1])) for i in range(k)]


class Parallelizer:
    def __init__(self, pool: ClonePool, straggler_factor: float = 2.0,
                 sync_seconds: float = SYNC_SECONDS_PER_CLONE,
                 clock: Optional[VirtualClock] = None):
        self.pool = pool
        self.straggler_factor = straggler_factor
        self.sync_seconds = sync_seconds
        if clock is not None:
            self.clock = ensure_clock(clock)
        elif getattr(pool.clock, "virtual", False):
            self.clock = pool.clock          # share the pool's timeline
        else:
            self.clock = VirtualClock()      # private deterministic timeline
        self.dispatcher = Dispatcher(self.pool, self.clock)

    def run(self, fn: Callable, shards: Sequence[tuple], *,
            clone_type: str = "main",
            merge: Callable = None,
            shard_delays: Optional[Sequence[float]] = None,
            venue_executor: Callable = None) -> ParallelResult:
        """Execute ``fn(*shard)`` across len(shards) clones, overlapped.

        ``venue_executor(clone, fn, shard) -> (value, venue_seconds)``
        defaults to running on the clone's venue spec.  ``shard_delays``
        injects extra venue-seconds per shard (tests / straggler demos).
        """
        k = len(shards)
        clock = self.clock
        t0 = clock.now()
        clones, provision_s = self.pool.acquire(clone_type, n=k)
        exec_start = t0 + provision_s
        if venue_executor is None:
            from repro.core.venues import Venue

            def venue_executor(clone, f, shard):
                return Venue(clone.spec).execute(f, *shard)

        def make_executor(i):
            def ex(clone, f, args):
                val, dt = venue_executor(clone, f, args)
                if shard_delays is not None:
                    dt += shard_delays[i]
                return val, dt
            return ex

        tasks = [self.dispatcher.submit(clone, fn, shard,
                                        executor=make_executor(i),
                                        extra_delay=provision_s,
                                        label=f"shard{i}")
                 for i, (clone, shard) in enumerate(zip(clones, shards))]
        done_at = [t.done_at for t in tasks]       # effective completion
        values = [t.value for t in tasks]

        # ---- straggler detection + re-dispatch, at event time ----
        redispatches = 0
        spares = []
        if k > 1:
            # advance until half the shards have completed, then set the
            # deadline from the median of what the timeline has shown so far
            order = sorted(range(k), key=lambda i: done_at[i])
            half = order[:(k + 1) // 2]
            clock.advance_to(max(done_at[i] for i in half))
            med = float(np.median([done_at[i] - exec_start for i in half]))
            deadline_t = exec_start + self.straggler_factor * max(med, 1e-9)
            stragglers = [i for i in order[(k + 1) // 2:]
                          if done_at[i] > max(deadline_t, clock.now())]
            if stragglers:
                clock.advance_to(max(deadline_t, clock.now()))
                for i in stragglers:
                    spare, spare_cost = self.pool.acquire(
                        clone_type, n=1, exclude_primary=True)
                    val, fresh = venue_executor(spare[0], fn, shards[i])
                    rescue_done = clock.now() + spare_cost + fresh
                    if rescue_done < done_at[i]:
                        values[i] = val
                        done_at[i] = rescue_done
                        redispatches += 1
                    spares.extend(spare)

        clock.advance_to(max(max(done_at), clock.now()))
        sync_s = self.sync_seconds * max(0, k - 1)
        clock.sleep(sync_s)
        shard_times = [t - exec_start for t in done_at]
        makespan = clock.now() - t0                # provision+max(shard)+sync
        merged = merge(values) if merge is not None else values
        self.pool.release(clones + spares)
        self.pool.reap_idle()
        return ParallelResult(merged, makespan, shard_times, provision_s,
                              sync_s, redispatches, k)
