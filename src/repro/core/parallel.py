"""Parallelization across clones (paper §7.4) + straggler mitigation.

The primary clone acts as a transparent proxy for k secondaries: shards are
dispatched, per-shard venue times collected, and the parallel makespan is
    resume(k) + max_i(shard_i) + sync(k) + merge
exactly mirroring the paper's accounting ("the resume time is included in
the overhead time, which in turn is included in the execution time").

Straggler mitigation (fleet requirement, DESIGN.md §8): shards whose venue
time exceeds ``straggler_factor x median`` are re-dispatched to a spare
clone; the effective shard time is the better of (original, detect + rerun).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.clones import ClonePool, resume_time

# Per-secondary synchronization cost charged by the primary proxy (paper:
# "incurring extra synchronization overheads"; calibrated so that 8-queens
# gains flatten past ~4 clones as in Fig. 12).
SYNC_SECONDS_PER_CLONE = 0.050


@dataclasses.dataclass
class ParallelResult:
    value: object
    makespan_s: float              # resume + max shard + sync + merge
    shard_times: List[float]
    resume_s: float
    sync_s: float
    redispatches: int
    n_clones: int


def split_batch(args: tuple, k: int, axis: int = 0) -> List[tuple]:
    """Default splitter: split every array leaf's leading axis into k parts."""
    import jax

    def split_leaf(leaf):
        return np.array_split(np.asarray(leaf), k, axis=axis)

    leaves, treedef = jax.tree.flatten(args)
    parts = [split_leaf(leaf) for leaf in leaves]
    return [jax.tree.unflatten(treedef, [p[i] for p in parts])
            for i in range(k)]


def split_range(lo: int, hi: int, k: int) -> List[tuple]:
    """Range splitter (paper: N-queens board regions)."""
    edges = np.linspace(lo, hi, k + 1).astype(int)
    return [(int(edges[i]), int(edges[i + 1])) for i in range(k)]


class Parallelizer:
    def __init__(self, pool: ClonePool, straggler_factor: float = 2.0,
                 sync_seconds: float = SYNC_SECONDS_PER_CLONE):
        self.pool = pool
        self.straggler_factor = straggler_factor
        self.sync_seconds = sync_seconds

    def run(self, fn: Callable, shards: Sequence[tuple], *,
            clone_type: str = "main",
            merge: Callable = None,
            shard_delays: Optional[Sequence[float]] = None,
            venue_executor: Callable = None) -> ParallelResult:
        """Execute ``fn(*shard)`` across len(shards) clones.

        ``venue_executor(clone, fn, shard) -> (value, venue_seconds)``
        defaults to running on the clone's venue spec.  ``shard_delays``
        injects extra venue-seconds per shard (tests / straggler demos).
        """
        k = len(shards)
        clones, provision_s = self.pool.acquire(clone_type, n=k)
        if venue_executor is None:
            from repro.core.venues import Venue

            def venue_executor(clone, f, shard):
                return Venue(clone.spec).execute(f, *shard)

        values, times = [], []
        for i, (clone, shard) in enumerate(zip(clones, shards)):
            val, dt = venue_executor(clone, fn, shard)
            if shard_delays is not None:
                dt += shard_delays[i]
            values.append(val)
            times.append(dt)

        # ---- straggler detection + re-dispatch ----
        redispatches = 0
        med = float(np.median(times))
        deadline = self.straggler_factor * max(med, 1e-9)
        for i, t in enumerate(times):
            if t > deadline and k > 1:
                spare, spare_cost = self.pool.acquire(clone_type, n=1,
                                                      exclude_primary=True)
                val, fresh = venue_executor(spare[0], fn, shards[i])
                rerun_total = deadline + spare_cost + fresh
                if rerun_total < t:
                    values[i] = val
                    times[i] = rerun_total
                    redispatches += 1
                self.pool.release(spare)

        sync_s = self.sync_seconds * max(0, k - 1)
        makespan = provision_s + max(times) + sync_s
        merged = merge(values) if merge is not None else values
        self.pool.release(clones)
        self.pool.reap_idle()
        return ParallelResult(merged, makespan, times, provision_s, sync_s,
                              redispatches, k)
