"""The Execution Controller (paper §4.3-4.4): profile-driven placement.

Decision procedure (faithful to the paper):
 - first encounter of a method: environment-only decision (offload iff the
   connection quality is good);
 - subsequently: predict (time, energy) for local vs remote from profiler
   history + current network state, apply the user policy;
 - remote path: serialize -> transfer -> [resume clones] -> execute ->
   return results + profiling data; OutOfMemoryError-equivalents escalate to
   a more powerful clone (paper §5.1/§7.3 image combiner); connection
   failures fall back to local execution and trigger async reconnection.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core import venues as V
from repro.core.clock import VirtualClock, ensure_clock
from repro.core.clones import ClonePool, CloneState
from repro.core.dispatch import Dispatcher
from repro.core.energy import PowerTutorModel
from repro.core.faults import FaultPlan, ReconnectManager, VenueFailure
from repro.core.parallel import Parallelizer
from repro.core.policy import Policy, Prediction, should_offload
from repro.core.profilers import (DeviceProfiler, NetworkProfiler,
                                  ProgramProfiler, size_bucket)
from repro.core.remoteable import RemoteableMethod


@dataclasses.dataclass
class ExecutionResult:
    value: Any
    offloaded: bool
    venue: str
    time_s: float                   # end-to-end scenario latency
    energy: Dict[str, float]        # client-side per-component joules
    overhead_s: float = 0.0         # transfer + provisioning
    tx_bytes: int = 0
    rx_bytes: int = 0
    escalations: int = 0
    fell_back: bool = False
    redispatches: int = 0
    n_clones: int = 1

    @property
    def energy_j(self) -> float:
        return sum(self.energy.values())


class ExecutionController:
    def __init__(self, policy: Policy = Policy.EXEC_TIME,
                 link: str = "wifi-local",
                 pool: Optional[ClonePool] = None,
                 clone_type: str = "main",
                 fault_plan: Optional[FaultPlan] = None,
                 phone: Optional[V.VenueSpec] = None,
                 clock: Optional[VirtualClock] = None):
        # decision layer (this class) + execution layer (Dispatcher) share
        # one virtual timeline; a supplied pool donates its clock when it
        # already has a virtual one
        if clock is not None:
            self.clock = ensure_clock(clock)
        elif pool is not None and getattr(pool.clock, "virtual", False):
            self.clock = pool.clock
        else:
            self.clock = VirtualClock()
        self.policy = policy
        self.pool = pool or ClonePool(link_name=link, clock=self.clock)
        self.clone_type = clone_type
        self.device = DeviceProfiler()
        self.device.observe(conn_subtype=link,
                            connectivity="cell" if link == "3g" else "wifi")
        self.network = NetworkProfiler(link)
        self.program = ProgramProfiler()
        self.phone_energy = PowerTutorModel()
        self.phone = V.Venue(phone or V.make_phone())
        self.faults = fault_plan or FaultPlan()
        self.reconnect = ReconnectManager()
        self.dispatcher = Dispatcher(self.pool, self.clock)
        self.parallelizer = Parallelizer(self.pool, clock=self.clock)
        self.decisions = {"local": 0, "remote": 0, "fallback": 0,
                          "escalations": 0}

    # ------------------------------------------------------------------ api
    def set_link(self, link: str) -> None:
        self.network.switch(link)
        self.pool.link = V.LINKS[link]
        self.device.observe(conn_subtype=link,
                            connectivity="cell" if link == "3g" else "wifi",)

    def execute(self, rm: RemoteableMethod, *args, n_clones: int = 1,
                clone_type: Optional[str] = None,
                force: Optional[str] = None, **kw) -> ExecutionResult:
        """Run a remoteable method under the current policy.

        ``force`` in {"local", "remote"} bypasses the decision (benchmarks).
        """
        clone_type = clone_type or self.clone_type
        skey = size_bucket(rm.size_key(*args, **kw))
        tx = V.pytree_bytes((args, kw))

        offload = self._decide(rm, skey, tx, force, n_clones)
        if not offload:
            return self._run_local(rm, skey, *args, **kw)
        try:
            return self._run_remote(rm, skey, tx, clone_type, n_clones,
                                    *args, **kw)
        except VenueFailure:
            # paper §4.4: fall back to local, discard the run's profiling
            # data, reconnect asynchronously
            self.decisions["fallback"] += 1
            self.reconnect.notify_failure()
            res = self._run_local(rm, skey, *args, record=False, **kw)
            return dataclasses.replace(res, fell_back=True)

    # ------------------------------------------------------------- decision
    def _decide(self, rm: RemoteableMethod, skey: int, tx: int,
                force: Optional[str], n_clones: int) -> bool:
        if force == "local":
            return False
        if force == "remote":
            return True
        if self.policy is Policy.NONE:
            return False
        if self.device.connection_quality() == "none":
            return False
        if not self.program.known(rm.name):
            # first encounter: environment-only (paper §4.3)
            return self.device.connection_quality() == "good"
        local = self._predict_local(rm, skey)
        remote = self._predict_remote(rm, skey, tx, n_clones)
        if local is None:
            return True
        if remote is None:
            return False
        return should_offload(self.policy, local, remote)

    def _predict_local(self, rm, skey) -> Optional[Prediction]:
        r = (self.program.lookup(rm.name, skey, "phone")
             or self.program.nearest(rm.name, skey, "phone"))
        if r is None or r.exec_time is None:
            rr = self.program.nearest(rm.name, skey, "cloud")
            if rr is None or rr.exec_time is None:
                return None
            # scale cloud history by the venue speed ratio
            ratio = self.pool.primary.spec.eff_flops / self.phone.spec.eff_flops
            t = rr.exec_time * ratio
        else:
            t = r.exec_time
        e = sum(self.phone_energy.local_exec_energy(t).values())
        return Prediction(t, e)

    def _predict_remote(self, rm, skey, tx: int,
                        n_clones: int) -> Optional[Prediction]:
        r = (self.program.lookup(rm.name, skey, "cloud")
             or self.program.nearest(rm.name, skey, "cloud"))
        if r is None or r.exec_time is None:
            rr = self.program.nearest(rm.name, skey, "phone")
            if rr is None or rr.exec_time is None:
                return None
            ratio = self.phone.spec.eff_flops / self.pool.primary.spec.eff_flops
            t_exec = rr.exec_time * ratio
        else:
            t_exec = r.exec_time
        t_exec = t_exec / max(1, n_clones)              # parallelizable part
        rx = (r.rx_bytes if r and r.rx_bytes else 1024)
        t_net = self.network.transfer_time(tx) + self.network.transfer_time(
            int(rx))
        t_resume = self._provision_estimate(n_clones)
        t_total = t_net + t_resume + t_exec
        link = self.network.active
        tx_seconds = t_net
        e = sum(self.phone_energy.offload_energy(
            t_total - tx_seconds, tx_seconds, link).values())
        return Prediction(t_total, e)

    def _provision_estimate(self, n: int) -> float:
        from repro.core.clones import BOOT_SECONDS, resume_time
        avail = [c for c in self.pool.clones
                 if not c.busy and c.ctype.name == self.clone_type]
        running = sum(c.state is CloneState.RUNNING for c in avail)
        paused = sum(c.state is CloneState.PAUSED for c in avail)
        need = max(0, n - running)
        if need == 0:
            return 0.0
        if need <= paused:
            return resume_time(need)
        return BOOT_SECONDS

    # ------------------------------------------------------------ execution
    def _run_local(self, rm, skey, *args, record: bool = True,
                   **kw) -> ExecutionResult:
        self.decisions["local"] += 1
        value, t = self.phone.execute(rm.callable(), *args, **kw)
        self.clock.sleep(t)                 # charge to the shared timeline
        energy = self.phone_energy.local_exec_energy(t)
        if record:
            self.program.record(rm.name, skey, "phone", exec_time=t,
                                energy=sum(energy.values()))
        return ExecutionResult(value, False, "phone", t, energy)

    def _run_remote(self, rm, skey, tx: int, clone_type: str, n_clones: int,
                    *args, **kw) -> ExecutionResult:
        self.decisions["remote"] += 1
        if self.faults.check():
            raise VenueFailure("connection lost during remote execution")

        if n_clones > 1 and rm.parallelizable:
            return self._run_parallel(rm, skey, tx, clone_type, n_clones,
                                      *args, **kw)

        t0 = self.clock.now()
        escalations = 0
        ctype = clone_type
        mem_need = rm.mem_fn(*args, **kw) if rm.mem_fn else 0
        clones, provision_s = self.pool.acquire(ctype, n=1)
        clone = clones[0]
        # OutOfMemoryError handling (paper §5.1): escalate to a more
        # powerful clone instead of surfacing the error to the client.
        while not V.Venue(clone.spec).fits(mem_need):
            nxt = self.pool.escalate_type(ctype)
            if nxt is None:
                break
            self.pool.release([clone])
            ctype = nxt
            clones, extra = self.pool.acquire(ctype, n=1)
            clone = clones[0]
            provision_s += extra
            escalations += 1
        self.decisions["escalations"] += escalations

        # upload, then provision + execute as one dispatched task whose
        # completion is an event on the timeline
        t_tx = self.network.transfer_time(tx)
        self.clock.sleep(t_tx)
        fn = rm.callable()
        call = (lambda *a: fn(*a, **kw)) if kw else fn
        task = self.dispatcher.submit(clone, call, args,
                                      extra_delay=provision_s, label=rm.name)
        self.dispatcher.wait([task])
        value, t_exec = task.value, task.venue_seconds
        rx = V.pytree_bytes(value)
        t_rx = self.network.transfer_time(rx)
        self.clock.sleep(t_rx)
        self.network.observe_transfer(tx + rx, t_tx + t_rx)
        self.network.observe_rtt(self.network.rtt())
        overhead = t_tx + t_rx + provision_s
        t_total = self.clock.now() - t0     # == overhead + t_exec
        energy = self.phone_energy.offload_energy(
            t_total - (t_tx + t_rx), t_tx + t_rx, self.network.active)
        self.program.record(rm.name, skey, "cloud", exec_time=t_exec,
                            energy=sum(energy.values()), tx=tx, rx=rx)
        self.pool.release(clones)
        self.pool.reap_idle()
        return ExecutionResult(value, True, clone.spec.name, t_total, energy,
                               overhead_s=overhead, tx_bytes=tx, rx_bytes=rx,
                               escalations=escalations)

    def _run_parallel(self, rm, skey, tx: int, clone_type: str, k: int,
                      *args, **kw) -> ExecutionResult:
        t0 = self.clock.now()
        shards = rm.split_fn(args, k)
        t_tx = self.network.transfer_time(tx)
        self.clock.sleep(t_tx)
        pres = self.parallelizer.run(rm.callable(), shards,
                                     clone_type=clone_type, merge=rm.merge_fn)
        rx = V.pytree_bytes(pres.value)
        t_rx = self.network.transfer_time(rx)
        self.clock.sleep(t_rx)
        # feed the network profiler exactly like the single-clone path, so
        # multi-clone runs keep bandwidth/RTT history fresh
        self.network.observe_transfer(tx + rx, t_tx + t_rx)
        self.network.observe_rtt(self.network.rtt())
        overhead = t_tx + t_rx + pres.resume_s + pres.sync_s
        t_total = self.clock.now() - t0     # == t_tx + makespan + t_rx
        energy = self.phone_energy.offload_energy(
            t_total - (t_tx + t_rx), t_tx + t_rx, self.network.active)
        self.program.record(rm.name, skey, "cloud",
                            exec_time=max(pres.shard_times),
                            energy=sum(energy.values()), tx=tx, rx=rx)
        return ExecutionResult(pres.value, True, f"{clone_type} x{k}",
                               t_total, energy, overhead_s=overhead,
                               tx_bytes=tx, rx_bytes=rx,
                               redispatches=pres.redispatches, n_clones=k)
