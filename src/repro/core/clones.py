"""Clone pool: the paper's VM manager (§5.3), adapted to TPU meshes.

Paper Table 1 (6 VM types) -> ``CLONE_TYPES``.  Paper VM states
powered-off / paused / running -> our cold / paused / running, with the TPU
cost structure (DESIGN.md §2): "boot" is XLA compilation (paper: ~32 s; XLA:
the same order), "resume" is reloading a cached executable + weights
(paper: ~300 ms), "running" is a warm executable.  The paper's observed
resume contention (7 simultaneous resumes -> 6-7 s) is modeled with a linear
contention factor, calibrated against their numbers.

The pool supports an injected clock so that scheduling behavior is
deterministic under test; with the default clock it tracks real time.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Callable, Dict, List, Optional

from repro.core.clock import ensure_clock
from repro.core.venues import LINKS, VenueSpec, make_cloud_vm, make_tpu_venue


class CloneState(enum.Enum):
    POWERED_OFF = "powered_off"
    PAUSED = "paused"
    RUNNING = "running"


@dataclasses.dataclass(frozen=True)
class CloneType:
    name: str
    cpus: int
    mem_mb: int
    heap_mb: int

    def rank(self) -> int:
        return self.cpus * self.mem_mb


# Paper Table 1, verbatim.
CLONE_TYPES: Dict[str, CloneType] = {
    "basic": CloneType("basic", 1, 200, 32),
    "main": CloneType("main", 1, 512, 100),
    "large": CloneType("large", 1, 1024, 100),
    "x2large": CloneType("x2large", 2, 1024, 100),
    "x4large": CloneType("x4large", 4, 1024, 100),
    "x8large": CloneType("x8large", 8, 1024, 100),
}

# Fleet adaptation: TPU sub-mesh clone types (chips per clone).
TPU_CLONE_TYPES: Dict[str, int] = {
    "tpu-1": 1, "tpu-4": 4, "tpu-16": 16, "tpu-64": 64,
    "tpu-pod": 256, "tpu-2pod": 512,
}

# Explicit CloneType -> TPU sub-mesh mapping for tpu=True pools.  The paper's
# VM ladder (Table 1) spans 1-8 CPUs; the TPU fleet's ladder spans sub-mesh
# sizes up to multi-pod, so the escalation path (basic -> ... -> x8large)
# must cover the whole TPU range — keying on the CPU count (the old
# ``tpu-{cpus}`` lookup) missed every type whose count has no same-named
# entry (x2large/x8large) and could never reach ``tpu-pod``/``tpu-2pod``.
TPU_BY_CLONE_TYPE: Dict[str, str] = {
    "basic": "tpu-1",
    "main": "tpu-4",
    "large": "tpu-16",
    "x2large": "tpu-64",
    "x4large": "tpu-pod",
    "x8large": "tpu-2pod",
}

# Fleet adaptation: on-demand $ price per clone type (per hour, EC2-2011-era
# ladder — the paper ran on Amazon EC2).  The placement engine (ADR-004)
# trades these rates against provisioning latency and energy.
USD_PER_HOUR: Dict[str, float] = {
    "basic": 0.02,
    "main": 0.085,
    "large": 0.17,
    "x2large": 0.34,
    "x4large": 0.68,
    "x8large": 1.36,
}


def usd_per_second(type_name: str) -> float:
    """On-demand $ per clone-second for a clone type."""
    return USD_PER_HOUR[type_name] / 3600.0


def chips_for(type_name: str, tpu: bool = False) -> int:
    """Per-type chip count: TPU sub-mesh chips for tpu pools, CPU count
    for the paper's cloud-VM pools — the quantity the chips-aware energy
    model bills (``TpuEnergyModel.energy_j(chips=...)``)."""
    if tpu:
        return TPU_CLONE_TYPES[TPU_BY_CLONE_TYPE[type_name]]
    return CLONE_TYPES[type_name].cpus


# Serving-layer KV capacity multiplier per clone type (ADR-004).  The
# escalation ladder must strictly widen the KV block pool at every step,
# which the paper's RAM column cannot express (flat at 1024 MB above
# ``large``); the TPU sub-mesh ladder (chips -> HBM) is the fleet's
# memory ladder, so it scales the per-type block budget for VM pools too.
KV_SCALE_BY_CLONE_TYPE: Dict[str, int] = {
    t: TPU_CLONE_TYPES[TPU_BY_CLONE_TYPE[t]] for t in CLONE_TYPES
}

# Transition-cost model, calibrated to the paper's §5.3 measurements.
RESUME_SECONDS = 0.300            # paused -> running
BOOT_SECONDS = 32.0               # powered_off -> running (VM boot / XLA jit)
CONTENTION_FACTOR = 3.3           # k simultaneous resumes: t = R*(1+f*(k-1))
PAUSE_IDLE_TTL = 30.0             # auto-pause after idle (s)
OFF_IDLE_TTL = 600.0              # auto-power-off after paused (s)

# Circuit-breaker defaults (ADR-006): consecutive dispatch failures on a
# clone trip its breaker open; after a cooldown a single half-open probe
# decides between closing it and re-opening with doubled cooldown.
CB_FAIL_THRESHOLD = 3             # consecutive failures -> open
CB_OPEN_SECONDS = 1.0             # first open -> half-open cooldown (s)
CB_MAX_OPEN_SECONDS = 30.0        # backoff cap for repeated re-opens (s)
CB_MAX_PROBES = 8                 # probe-chain length per clock binding


def resume_time(k_simultaneous: int) -> float:
    """Paper: 1 resume ~300 ms, 7 simultaneous -> 6-7 s (super-linear)."""
    k = max(1, k_simultaneous)
    return RESUME_SECONDS * (1.0 + CONTENTION_FACTOR * (k - 1))


class CloneHealth(enum.Enum):
    HEALTHY = "healthy"     # serving normally
    SUSPECT = "suspect"     # recovered from a fault, awaiting a probe
    DEAD = "dead"           # failed; only a successful probe revives it


class CircuitBreaker:
    """Per-clone circuit breaker (ADR-006): closed → open on the fail
    threshold (or a hard :meth:`trip`), half-open after a cooldown, and
    back to closed only when a probe succeeds.  ``bind`` attaches a
    VirtualClock and a probe callable, after which every open schedules
    its own half-open probe event with capped exponential backoff;
    without a clock the classic :meth:`allow` gate drives the
    transitions instead."""

    def __init__(self, fail_threshold: int = CB_FAIL_THRESHOLD,
                 open_seconds: float = CB_OPEN_SECONDS,
                 max_open_seconds: float = CB_MAX_OPEN_SECONDS,
                 max_probes: int = CB_MAX_PROBES):
        self.fail_threshold = fail_threshold
        self.open_seconds = open_seconds
        self.max_open_seconds = max_open_seconds
        self.max_probes = max_probes
        self.state = "closed"            # closed | open | half_open
        self.failures = 0                # consecutive, reset on success
        self.opened_at = 0.0
        self.opens = 0                   # lifetime open transitions
        self.probes = 0                  # lifetime half-open probes
        self._cooldown = open_seconds
        self._clock = None
        self._probe_fn: Optional[Callable[[], bool]] = None
        self._probe_ev = None

    def bind(self, clock, probe_fn: Callable[[], bool]) -> None:
        """Attach a clock + probe; resets the probe-chain budget."""
        self._clock = clock
        self._probe_fn = probe_fn
        self.probes = 0

    def record_failure(self, now: float) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.fail_threshold:
            self.trip(now)

    def trip(self, now: float) -> None:
        """Force-open (a clone death is definitive, no threshold)."""
        reopening = self.state != "closed"
        self.state = "open"
        self.opened_at = now
        self.opens += 1
        if reopening:      # half-open probe failed: back off the cooldown
            self._cooldown = min(self._cooldown * 2, self.max_open_seconds)
        if self._clock is not None and self.probes < self.max_probes:
            self._probe_ev = self._clock.schedule(self._cooldown,
                                                  self._probe)
    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0
        self._cooldown = self.open_seconds
        if self._probe_ev is not None:
            self._probe_ev.cancel()
            self._probe_ev = None

    def allow(self, now: float) -> bool:
        """Dispatch gate for clock-less use: closed always allows; open
        allows one trial once the cooldown has elapsed (transitioning to
        half-open); half-open allows nothing until the trial reports."""
        if self.state == "closed":
            return True
        if self.state == "open" and now >= self.opened_at + self._cooldown:
            self.state = "half_open"
            return True
        return False

    def _probe(self) -> None:
        """Scheduled half-open probe: success closes, failure re-opens
        with doubled cooldown (next probe auto-scheduled, chain capped)."""
        if self.state != "open" or self._probe_fn is None:
            return
        self.state = "half_open"
        self.probes += 1
        if self._probe_fn():
            self.record_success()
        else:
            self.trip(self._clock.now())


@dataclasses.dataclass
class Clone:
    cid: int
    ctype: CloneType
    spec: VenueSpec
    state: CloneState = CloneState.POWERED_OFF
    is_primary: bool = False
    last_used: float = 0.0
    busy: bool = False
    executable_cache: dict = dataclasses.field(default_factory=dict)
    # $-accounting (ADR-004): clone-seconds accrue while RUNNING — an idle
    # running clone still bills, which is what makes TTL pausing worth $
    running_since: Optional[float] = None
    running_seconds: float = 0.0
    # fault tolerance (ADR-006): health gates placement, the breaker
    # gates re-use after failures, slowdown scales dispatched venue time
    health: CloneHealth = CloneHealth.HEALTHY
    breaker: CircuitBreaker = dataclasses.field(
        default_factory=CircuitBreaker)
    slowdown: float = 1.0

    @property
    def warm(self) -> bool:
        return bool(self.executable_cache)

    @property
    def serveable(self) -> bool:
        """Placement-eligible: healthy with a closed breaker.  Callers
        still check RUNNING/busy — this is the fault gate only."""
        return (self.health is CloneHealth.HEALTHY
                and self.breaker.state == "closed")


class ClonePool:
    """On-demand allocation of clones (paper §5.3), primary + secondaries."""

    def __init__(self, link_name: str = "wifi-local",
                 clock: Optional[Callable[[], float]] = None,
                 max_clones: int = 64, tpu: bool = False,
                 breaker_kwargs: Optional[Dict[str, float]] = None):
        # one injected timeline: a clock object, a bare callable (tests), or
        # None for a fresh deterministic VirtualClock
        self.clock = ensure_clock(clock)
        self.link = LINKS[link_name]
        self.max_clones = max_clones
        self.tpu = tpu
        # non-default CircuitBreaker ctor args (e.g. max_open_seconds,
        # max_probes) applied to every clone this pool creates — must be
        # set before the primary below
        self.breaker_kwargs = dict(breaker_kwargs or {})
        self._ids = itertools.count()
        self.clones: List[Clone] = []
        self.stats = {"resumes": 0, "boots": 0, "pauses": 0, "offs": 0,
                      "resume_seconds": 0.0, "boot_seconds": 0.0}
        # the primary server is always online (paper: "main server")
        self.primary = self._new_clone("main", primary=True)
        self.primary.state = CloneState.RUNNING
        self.primary.running_since = self.clock()

    # ---------------------------------------------------------------- utils
    def _make_spec(self, ctype: CloneType) -> VenueSpec:
        if self.tpu:
            tpu_name = TPU_BY_CLONE_TYPE[ctype.name]
            chips = TPU_CLONE_TYPES[tpu_name]
            return make_tpu_venue(tpu_name, chips, self.link)
        return make_cloud_vm(ctype.name, ctype.cpus, ctype.mem_mb,
                             ctype.heap_mb, self.link)

    def _new_clone(self, type_name: str, primary: bool = False) -> Clone:
        ctype = CLONE_TYPES[type_name]
        clone = Clone(next(self._ids), ctype, self._make_spec(ctype),
                      is_primary=primary, last_used=self.clock())
        if self.breaker_kwargs:
            clone.breaker = CircuitBreaker(**self.breaker_kwargs)
        self.clones.append(clone)
        return clone

    def running(self) -> List[Clone]:
        return [c for c in self.clones if c.state is CloneState.RUNNING]

    # ------------------------------------------------------- $-accounting
    def _mark_running(self, clone: Clone, now: float) -> None:
        """Open a billing interval (idempotent for already-running clones)."""
        if clone.running_since is None:
            clone.running_since = now

    def _mark_stopped(self, clone: Clone, now: float) -> None:
        """Close the billing interval on pause / power-off."""
        if clone.running_since is not None:
            clone.running_seconds += now - clone.running_since
            clone.running_since = None

    def clone_seconds_by_type(self, now: Optional[float] = None
                              ) -> Dict[str, float]:
        """RUNNING clone-seconds accrued so far, per clone type (live
        intervals included up to ``now``) — the quantity the $-cost model
        bills (primary included: the always-on main server is a standing
        cost the fleet pays whether or not it serves)."""
        now = self.clock() if now is None else now
        out: Dict[str, float] = {}
        for c in self.clones:
            s = c.running_seconds
            if c.running_since is not None:
                s += now - c.running_since
            if s > 0.0:
                out[c.ctype.name] = out.get(c.ctype.name, 0.0) + s
        return out

    def cost_usd(self, now: Optional[float] = None) -> float:
        """Total on-demand $ cost of the fleet's running time so far."""
        return sum(usd_per_second(t) * s
                   for t, s in self.clone_seconds_by_type(now).items())

    def provision(self, type_name: str, n: int,
                  state: CloneState = CloneState.PAUSED) -> List[Clone]:
        """Pre-create secondaries (paper: 'secondary clones are kept in
        pause state to minimize the resources allocated')."""
        out = []
        now = self.clock()
        for _ in range(n):
            c = self._new_clone(type_name)
            c.state = state
            if state is CloneState.RUNNING:
                self._mark_running(c, now)
            out.append(c)
        return out

    # ------------------------------------------------------------- lifecycle
    def acquire(self, type_name: str = "main", n: int = 1,
                exclude_primary: bool = False) -> tuple:
        """Resume/boot n clones of the given type.

        Returns (clones, provisioning_seconds) — the latency cost charged to
        the request (paper: resume time is part of the execution overhead).
        """
        want = CLONE_TYPES[type_name]
        ready, to_resume, to_boot = [], [], []
        for c in self.clones:
            if len(ready) + len(to_resume) + len(to_boot) >= n:
                break
            if c.busy or (exclude_primary and c.is_primary):
                continue
            if c.ctype.name != type_name or not c.serveable:
                continue
            if c.state is CloneState.RUNNING:
                ready.append(c)
            elif c.state is CloneState.PAUSED:
                to_resume.append(c)
            else:
                to_boot.append(c)
        while len(ready) + len(to_resume) + len(to_boot) < n:
            if len(self.clones) >= self.max_clones:
                raise RuntimeError("clone pool exhausted")
            to_boot.append(self._new_clone(type_name))

        cost = 0.0
        if to_resume:
            dt = resume_time(len(to_resume))
            cost = max(cost, dt)
            self.stats["resumes"] += len(to_resume)
            self.stats["resume_seconds"] += dt
        if to_boot:
            cost = max(cost, BOOT_SECONDS)
            self.stats["boots"] += len(to_boot)
            self.stats["boot_seconds"] += BOOT_SECONDS * len(to_boot)
        now = self.clock()
        out = ready + to_resume + to_boot
        for c in out:
            c.state = CloneState.RUNNING
            self._mark_running(c, now)
            c.busy = True
            c.last_used = now
        return out, cost

    def release(self, clones) -> None:
        now = self.clock()
        for c in clones:
            c.busy = False
            c.last_used = now

    def pause(self, clone: Clone) -> None:
        if clone.is_primary or clone.state is not CloneState.RUNNING:
            return
        self._mark_stopped(clone, self.clock())
        clone.state = CloneState.PAUSED
        self.stats["pauses"] += 1

    def power_off(self, clone: Clone) -> None:
        if clone.is_primary:
            return
        self._mark_stopped(clone, self.clock())
        clone.state = CloneState.POWERED_OFF
        clone.executable_cache.clear()
        self.stats["offs"] += 1

    def reap_idle(self) -> None:
        """Paper: the Client Handler pauses/offs idle secondaries."""
        now = self.clock()
        for c in self.clones:
            if c.is_primary or c.busy:
                continue
            idle = now - c.last_used
            if c.state is CloneState.RUNNING and idle > PAUSE_IDLE_TTL:
                self.pause(c)
            elif c.state is CloneState.PAUSED and idle > OFF_IDLE_TTL:
                self.power_off(c)

    # ------------------------------------------------------------ elasticity
    def running_secondaries(self, type_name: Optional[str] = None
                            ) -> List[Clone]:
        return [c for c in self.clones
                if not c.is_primary and c.state is CloneState.RUNNING
                and (type_name is None or c.ctype.name == type_name)]

    def ensure_secondaries(self, type_name: str, n: int
                           ) -> tuple:
        """Scale up: resume/boot until >= n secondaries of this type RUN.

        Unlike :meth:`acquire` the clones are left *idle* (not busy) — this
        is the Client Handler's capacity knob, not a per-request grab.
        Returns (newly_activated_clones, per_clone_ready_seconds): a resumed
        clone is usable after the (contended) resume time, a booted one only
        after the full boot — they must not share one aggregate delay.
        """
        have = len(self.running_secondaries(type_name))
        if have >= n:
            return [], []
        need = n - have
        # dead / suspect clones are not capacity: a failed secondary sits
        # powered off until its breaker's probe revives it (ADR-006)
        to_resume = [c for c in self.clones
                     if not c.is_primary and c.ctype.name == type_name
                     and c.serveable
                     and c.state is CloneState.PAUSED][:need]
        n_boot = need - len(to_resume)
        to_boot = [c for c in self.clones
                   if not c.is_primary and c.ctype.name == type_name
                   and c.serveable
                   and c.state is CloneState.POWERED_OFF][:n_boot]
        while len(to_resume) + len(to_boot) < need:
            if len(self.clones) >= self.max_clones:
                break
            to_boot.append(self._new_clone(type_name))
        costs = []
        if to_resume:
            dt = resume_time(len(to_resume))
            costs += [dt] * len(to_resume)
            self.stats["resumes"] += len(to_resume)
            self.stats["resume_seconds"] += dt
        if to_boot:
            costs += [BOOT_SECONDS] * len(to_boot)
            self.stats["boots"] += len(to_boot)
            self.stats["boot_seconds"] += BOOT_SECONDS * len(to_boot)
        now = self.clock()
        out = to_resume + to_boot
        for c in out:
            c.state = CloneState.RUNNING
            self._mark_running(c, now)
            c.last_used = now
        return out, costs

    def pause_surplus(self, keep: int, type_name: Optional[str] = None
                      ) -> int:
        """Scale down: pause idle running secondaries beyond ``keep``."""
        idle = [c for c in self.running_secondaries(type_name)
                if not c.busy]
        paused = 0
        for c in idle[max(0, keep):]:
            self.pause(c)
            paused += 1
        return paused

    # ------------------------------------------------------------ escalation
    def escalate_type(self, type_name: str) -> Optional[str]:
        """Next more powerful clone type (paper: OutOfMemoryError handling)."""
        order = sorted(CLONE_TYPES.values(), key=CloneType.rank)
        names = [t.name for t in order]
        i = names.index(type_name)
        return names[i + 1] if i + 1 < len(names) else None
