"""Client Handler scheduling primitives (paper §5.2-§5.3).

The paper's Client Handler "manages the connections coming from multiple
clients" and drives the VM manager's elasticity.  This module holds the
request-level pieces the event-driven :class:`~repro.launch.serve.ClientHandler`
is built from:

``AdmissionQueue``
    Bounded FIFO with admission control — offered load beyond the bound is
    rejected up front (shed) rather than queued into unbounded latency.

``PoissonArrivals``
    Deterministic (seeded) open-loop arrival process for load generation on
    the virtual timeline.

``QueueAutoscaler``
    Queue-depth-driven elasticity: grows the RUNNING secondary set through
    :meth:`ClonePool.ensure_secondaries` when demand outruns capacity, and
    lets the pool's idle TTLs (:meth:`ClonePool.reap_idle`) pause/power-off
    surplus clones — exactly the paper's "secondary clones are kept in pause
    state to minimize the resources allocated" policy, now measurable.

Provisioning latency is *not* hidden: newly activated clones carry a
``ready_at`` timestamp and the handler must not start work on them before
it (resume ~300 ms, boot ~32 s on the shared timeline).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.core.clones import ClonePool


@dataclasses.dataclass
class ServeRequest:
    """One client request to the serving fleet.

    ``priority`` orders preemption victim selection (lower = evicted
    first); it never reorders the FIFO admission queue.  The restore
    fields are written by the serving layer when a slot is *preempted*
    (KV blocks reclaimed mid-decode, ADR-003): ``generated`` carries the
    tokens already emitted so a restore resumes instead of restarting,
    ``first_token_t`` preserves the client-visible TTFT, and
    ``preemptions`` counts how often this request was evicted.
    """

    rid: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    arrival_t: float = 0.0           # offered-load timestamp (virtual)
    admitted_t: Optional[float] = None
    priority: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_t: Optional[float] = None
    preemptions: int = 0


@dataclasses.dataclass
class ServeCompletion:
    """A finished request with its client-visible timeline stamps."""

    rid: int
    tokens: List[int]
    arrival_t: float
    first_token_t: float
    done_t: float
    venue: str

    @property
    def latency_s(self) -> float:
        """End-to-end request latency: arrival to last token."""
        return self.done_t - self.arrival_t

    @property
    def ttft_s(self) -> float:
        """Time to first token: arrival to the first emitted token."""
        return self.first_token_t - self.arrival_t


class AdmissionQueue:
    """Bounded request queue; beyond ``max_depth`` arrivals are shed."""

    def __init__(self, max_depth: int = 64):
        self.max_depth = max_depth
        self._q: Deque[ServeRequest] = deque()
        self.accepted = 0
        self.rejected = 0

    def offer(self, req: ServeRequest, now: float) -> bool:
        """Admit ``req`` (stamping ``admitted_t``) or shed it; returns
        True when admitted."""
        if len(self._q) >= self.max_depth:
            self.rejected += 1
            return False
        req.admitted_t = now
        self._q.append(req)
        self.accepted += 1
        return True

    def take(self, n: int) -> List[ServeRequest]:
        """Pop up to ``n`` requests in FIFO order."""
        out = []
        while self._q and len(out) < n:
            out.append(self._q.popleft())
        return out

    def requeue(self, req: ServeRequest) -> None:
        """Return a *preempted* request to the head of the queue.

        The request was already admitted once (it counted toward
        ``accepted`` and holds its original ``admitted_t``), so it bypasses
        the depth bound — preemption must never turn into load shedding —
        and goes to the *front*: evicted work restores before any fresh
        arrival is admitted.  Among several evictions in one exhaustion
        round this is LIFO (the most recent eviction restores first);
        starvation is bounded because every restored request's remaining
        budget only shrinks."""
        self._q.appendleft(req)

    def peek(self) -> Optional[ServeRequest]:
        """The request ``take`` would pop next, without popping it."""
        return self._q[0] if self._q else None

    @property
    def depth(self) -> int:
        """Requests currently queued (admitted, not yet taken)."""
        return len(self._q)


def poisson_arrivals(rate: float, n: int, *, seed: int = 0,
                     prompt_len: int = 8, vocab: int = 256,
                     max_new_tokens: int = 8, start: float = 0.0,
                     prefix_len: int = 0,
                     prefix_share: float = 1.0) -> List[ServeRequest]:
    """Open-loop Poisson arrival trace (seeded, deterministic).

    ``prefix_len > 0`` models a shared system prompt: a fraction
    ``prefix_share`` of requests start with one common ``prefix_len``-token
    prefix (drawn once per seed) followed by a random tail, the rest stay
    fully random — the workload shape the block-level prefix cache exists
    for (thousands of users, one system prompt).  The trace is identical
    for a given seed whatever serving configuration consumes it."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=prefix_len, dtype=np.int32)
    t = start
    out = []
    for i in range(n):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        prompt = rng.integers(0, vocab, size=prompt_len, dtype=np.int32)
        if prefix_len > 0 and rng.random() < prefix_share:
            prompt[:prefix_len] = prefix
        out.append(ServeRequest(i, prompt, max_new_tokens, arrival_t=t))
    return out


class SlotLedger:
    """Open decode slots across in-flight engines (paged serving).

    The admission policy the paged Client Handler consults *before* it
    spawns new engines: queued requests are offered to partially-full
    in-flight engines first (a mid-flight cohort join — ThinkAir's
    dynamic-provisioning claim at the request level), and only residual
    demand counts toward autoscaling.  Keys are opaque engine handles; the
    ledger holds only free-slot counts, never requests.
    """

    def __init__(self):
        self._free: Dict[object, int] = {}

    def update(self, key, free_slots: int) -> None:
        """Record that engine ``key`` has ``free_slots`` open slots."""
        if free_slots > 0:
            self._free[key] = free_slots
        else:
            self._free.pop(key, None)

    def drop(self, key) -> None:
        """Forget a retired engine."""
        self._free.pop(key, None)

    @staticmethod
    def pick_victim(candidates) -> Optional[int]:
        """Priority-ordered preemption policy (ADR-003).

        ``candidates``: iterable of ``(slot, priority, generated_tokens)``
        for the engine's active slots when its KV pool exhausts mid-decode.
        The victim is the slot with the *lowest priority*; among equals,
        the one with the *fewest generated tokens* (cheapest to restore —
        its re-prefill suffix is shortest and its prompt blocks are most
        likely still resident in the prefix cache); remaining ties break
        by highest slot id, so the choice is deterministic.  Returns the
        victim slot, or None when there is no candidate."""
        best = min(candidates, key=lambda c: (c[1], c[2], -c[0]),
                   default=None)
        return None if best is None else best[0]

    @property
    def total_free(self) -> int:
        return sum(self._free.values())

    def assign(self, queue: "AdmissionQueue",
               fits: Optional[Callable] = None,
               on_assign: Optional[Callable] = None) -> List[tuple]:
        """Drain the queue into open slots; returns [(key, request)].

        Tightest-fit first: the engine with the fewest open slots is
        filled before emptier ones, so nearly-drained engines refill (and
        surplus clones go idle for the TTL reaper) instead of every engine
        hovering half-full.  Deterministic: ties break by insertion order.

        ``fits(key, request) -> bool`` (optional) is re-checked per
        assignment so engines can veto on resources beyond slot count —
        e.g. KV block commitments; a vetoing engine leaves this round.
        ``on_assign(key, request)`` (optional) runs *immediately* after
        each pop, before the next ``fits`` check — admission must happen
        here so resource checks see the commitments of earlier
        assignments in the same round, not stale pre-round state.
        """
        out = []
        while queue.depth > 0 and self._free:
            key = min(self._free, key=self._free.get)  # type: ignore[arg-type]
            if fits is not None and not fits(key, queue.peek()):
                del self._free[key]        # can't take the head request
                continue
            req = queue.take(1)[0]
            out.append((key, req))
            if on_assign is not None:
                on_assign(key, req)
            self._free[key] -= 1
            if self._free[key] <= 0:
                del self._free[key]
        return out


class QueueAutoscaler:
    """Queue-depth-driven elastic sizing of the RUNNING secondary set.

    Target size = ceil(demand / work_per_clone) where demand counts queued
    requests plus in-flight work units; clamped to [min_secondaries,
    max_secondaries].  Growth provisions through the pool (resume preferred
    over boot — costs land on the shared timeline via ``ready_at``);
    shrink is delegated to the pool's idle TTLs via ``reap_idle``.
    """

    def __init__(self, pool: ClonePool, clone_type: str = "main",
                 work_per_clone: int = 1, min_secondaries: int = 0,
                 max_secondaries: int = 8):
        self.pool = pool
        self.clone_type = clone_type
        self.work_per_clone = max(1, work_per_clone)
        self.min_secondaries = min_secondaries
        self.max_secondaries = max_secondaries
        self.ready_at: Dict[int, float] = {}     # cid -> usable-from time
        self.peak_secondaries = 0
        self.scale_ups = 0
        self.samples: List[tuple] = []           # (t, running_secondaries)

    def clone_ready_delay(self, clone, now: float) -> float:
        """Seconds until ``clone`` is actually usable (0 if warm)."""
        return max(0.0, self.ready_at.get(clone.cid, 0.0) - now)

    def step(self, now: float, queue_depth: int, in_flight: int) -> int:
        """One control-loop tick; returns the current target size."""
        demand = queue_depth + in_flight
        target = min(self.max_secondaries,
                     max(self.min_secondaries,
                         math.ceil(demand / self.work_per_clone)))
        running = len(self.pool.running_secondaries(self.clone_type))
        if target > running:
            fresh, costs = self.pool.ensure_secondaries(self.clone_type,
                                                        target)
            for c, cost in zip(fresh, costs):
                self.ready_at[c.cid] = now + cost
            if fresh:
                self.scale_ups += 1
        elif running > self.max_secondaries:      # cap shrank under us
            self.pool.pause_surplus(self.max_secondaries, self.clone_type)
        # shrink: TTL-driven (paper: idle secondaries are paused, then off)
        self.pool.reap_idle()
        running = len(self.pool.running_secondaries(self.clone_type))
        self.peak_secondaries = max(self.peak_secondaries, running)
        self.samples.append((now, running))
        return target
