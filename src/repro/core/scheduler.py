"""Client Handler scheduling primitives (paper §5.2-§5.3).

The paper's Client Handler "manages the connections coming from multiple
clients" and drives the VM manager's elasticity.  This module holds the
request-level pieces the event-driven :class:`~repro.launch.serve.ClientHandler`
is built from:

``AdmissionQueue``
    Bounded FIFO with admission control — offered load beyond the bound is
    rejected up front (shed) rather than queued into unbounded latency.

``PoissonArrivals``
    Deterministic (seeded) open-loop arrival process for load generation on
    the virtual timeline.

``PlacementEngine``
    Cost/energy-aware tier selection (ADR-004): for one demand bucket it
    ranks the eligible clone-type tiers by a :func:`~repro.core.policy.
    placement_key` over (provisioning latency, $-rate, chips-aware energy
    rate), and walks :meth:`ClonePool.escalate_type` to find the smallest
    tier whose KV block pool can hold a request — the serving-layer
    analogue of the paper's OutOfMemoryError -> bigger-VM flow (§5.4).

``FleetAutoscaler``
    Heterogeneous elasticity: demand arrives as *buckets* per (required
    tier, urgency) — the Client Handler derives them per tenant/priority
    class and per KV-footprint — each bucket is placed onto a tier by the
    ``PlacementEngine``, and per-type targets grow the RUNNING secondary
    set through :meth:`ClonePool.ensure_secondaries` under one global
    cap; shrink stays TTL-driven (:meth:`ClonePool.reap_idle`) — the
    paper's "secondary clones are kept in pause state" policy.

Provisioning latency is *not* hidden: newly activated clones carry a
``ready_at`` timestamp and the handler must not start work on them before
it (resume ~300 ms, boot ~32 s on the shared timeline).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.clones import (BOOT_SECONDS, CLONE_TYPES, ClonePool,
                               CloneState, chips_for, resume_time,
                               usd_per_second)
from repro.core.energy import TpuEnergyModel
from repro.core.policy import (PLACEMENT_HORIZON_S, Policy, Prediction,
                               placement_key)


@dataclasses.dataclass
class ServeRequest:
    """One client request to the serving fleet.

    ``priority`` orders preemption victim selection (lower = evicted
    first); it never reorders the FIFO admission queue.  The restore
    fields are written by the serving layer when a slot is *preempted*
    (KV blocks reclaimed mid-decode, ADR-003): ``generated`` carries the
    tokens already emitted so a restore resumes instead of restarting,
    ``first_token_t`` preserves the client-visible TTFT, and
    ``preemptions`` counts how often this request was evicted.

    The SLO fields are read by the gateway (ADR-007): ``slo`` classes
    the request ("interactive" vs "batch"), ``deadline_s`` is a relative
    end-to-end latency target fixed at arrival (None = best-effort),
    ``token_ts`` carries streamed delivery timestamps across preempt /
    restore so TPOT survives eviction, and ``retries`` counts
    Retry-After replays of a shed request.
    """

    rid: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    arrival_t: float = 0.0           # offered-load timestamp (virtual)
    admitted_t: Optional[float] = None
    priority: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_t: Optional[float] = None
    preemptions: int = 0
    tenant: Optional[str] = None     # multi-tenant demand bucketing
    slo: str = "batch"               # SLO class: "interactive" | "batch"
    deadline_s: Optional[float] = None   # latency target (relative)
    token_ts: List[float] = dataclasses.field(default_factory=list)
    retries: int = 0                 # gateway Retry-After replays
    # speculative decoding (ADR-008): per-request draft acceptance-rate
    # EMA — carried on the request so preemption / migration / restore
    # keep the adaptive window K where the request left off
    spec_ema: float = 1.0


@dataclasses.dataclass
class ServeCompletion:
    """A finished request with its client-visible timeline stamps.

    ``token_ts`` holds per-token streamed delivery times (same length as
    ``tokens``), ``cached`` marks responses served from the gateway's
    response cache without touching the fleet."""

    rid: int
    tokens: List[int]
    arrival_t: float
    first_token_t: float
    done_t: float
    venue: str
    tenant: Optional[str] = None
    slo: str = "batch"
    deadline_s: Optional[float] = None
    token_ts: List[float] = dataclasses.field(default_factory=list)
    cached: bool = False

    @property
    def latency_s(self) -> float:
        """End-to-end request latency: arrival to last token."""
        return self.done_t - self.arrival_t

    @property
    def ttft_s(self) -> float:
        """Time to first token: arrival to the first emitted token."""
        return self.first_token_t - self.arrival_t

    @property
    def tpot_s(self) -> float:
        """Mean time per output token after the first, from streamed
        delivery stamps (0.0 for single-token or unstamped replies)."""
        n = len(self.tokens)
        if n > 1 and len(self.token_ts) == n:
            return (self.token_ts[-1] - self.token_ts[0]) / (n - 1)
        if n > 1:
            return (self.done_t - self.first_token_t) / (n - 1)
        return 0.0

    @property
    def met_deadline(self) -> bool:
        """True when the request had no deadline or finished inside it."""
        return (self.deadline_s is None
                or self.latency_s <= self.deadline_s + 1e-9)


class AdmissionQueue:
    """Bounded request queue; beyond ``max_depth`` arrivals are shed."""

    def __init__(self, max_depth: int = 64):
        self.max_depth = max_depth
        self._q: Deque[ServeRequest] = deque()
        self.accepted = 0
        self.rejected = 0

    def offer(self, req: ServeRequest, now: float) -> bool:
        """Admit ``req`` (stamping ``admitted_t``) or shed it; returns
        True when admitted."""
        if len(self._q) >= self.max_depth:
            self.rejected += 1
            return False
        req.admitted_t = now
        self._q.append(req)
        self.accepted += 1
        return True

    def take(self, n: int) -> List[ServeRequest]:
        """Pop up to ``n`` requests in FIFO order."""
        out = []
        while self._q and len(out) < n:
            out.append(self._q.popleft())
        return out

    def requeue(self, req: ServeRequest) -> None:
        """Return a *preempted* request to the head of the queue.

        The request was already admitted once (it counted toward
        ``accepted`` and holds its original ``admitted_t``), so it bypasses
        the depth bound — preemption must never turn into load shedding —
        and goes to the *front*: evicted work restores before any fresh
        arrival is admitted.  Among several evictions in one exhaustion
        round this is LIFO (the most recent eviction restores first);
        starvation is bounded because every restored request's remaining
        budget only shrinks."""
        self._q.appendleft(req)

    def peek(self) -> Optional[ServeRequest]:
        """The request ``take`` would pop next, without popping it."""
        return self._q[0] if self._q else None

    def snapshot(self) -> List[ServeRequest]:
        """The queued requests in FIFO order (read-only view for demand
        bucketing and placement — never mutate the returned requests'
        queue membership directly)."""
        return list(self._q)

    def take_where(self, pred: Callable[["ServeRequest"], bool]
                   ) -> Optional[ServeRequest]:
        """Pop the *first* queued request satisfying ``pred`` (FIFO scan).

        The heterogeneous spawn path uses this so a head request whose
        required tier is still provisioning (e.g. a long-context request
        waiting for a ``large`` boot) does not head-of-line-block the
        short-prompt bulk behind it."""
        for i, r in enumerate(self._q):
            if pred(r):
                del self._q[i]
                return r
        return None

    @property
    def depth(self) -> int:
        """Requests currently queued (admitted, not yet taken)."""
        return len(self._q)


def poisson_arrivals(rate: float, n: int, *, seed: int = 0,
                     prompt_len: int = 8, vocab: int = 256,
                     max_new_tokens: int = 8, start: float = 0.0,
                     prefix_len: int = 0,
                     prefix_share: float = 1.0) -> List[ServeRequest]:
    """Open-loop Poisson arrival trace (seeded, deterministic).

    ``prefix_len > 0`` models a shared system prompt: a fraction
    ``prefix_share`` of requests start with one common ``prefix_len``-token
    prefix (drawn once per seed) followed by a random tail, the rest stay
    fully random — the workload shape the block-level prefix cache exists
    for (thousands of users, one system prompt).  The trace is identical
    for a given seed whatever serving configuration consumes it."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=prefix_len, dtype=np.int32)
    t = start
    out = []
    for i in range(n):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        prompt = rng.integers(0, vocab, size=prompt_len, dtype=np.int32)
        if prefix_len > 0 and rng.random() < prefix_share:
            prompt[:prefix_len] = prefix
        out.append(ServeRequest(i, prompt, max_new_tokens, arrival_t=t))
    return out


class SlotLedger:
    """Open decode slots across in-flight engines (paged serving).

    The admission policy the paged Client Handler consults *before* it
    spawns new engines: queued requests are offered to partially-full
    in-flight engines first (a mid-flight cohort join — ThinkAir's
    dynamic-provisioning claim at the request level), and only residual
    demand counts toward autoscaling.  Keys are opaque engine handles; the
    ledger holds only free-slot counts, never requests.
    """

    def __init__(self):
        self._free: Dict[object, int] = {}

    def update(self, key, free_slots: int) -> None:
        """Record that engine ``key`` has ``free_slots`` open slots."""
        if free_slots > 0:
            self._free[key] = free_slots
        else:
            self._free.pop(key, None)

    def drop(self, key) -> None:
        """Forget a retired engine."""
        self._free.pop(key, None)

    @staticmethod
    def pick_victim(candidates) -> Optional[int]:
        """Priority-ordered preemption policy (ADR-003).

        ``candidates``: iterable of ``(slot, priority, generated_tokens)``
        for the engine's active slots when its KV pool exhausts mid-decode.
        The victim is the slot with the *lowest priority*; among equals,
        the one with the *fewest generated tokens* (cheapest to restore —
        its re-prefill suffix is shortest and its prompt blocks are most
        likely still resident in the prefix cache); remaining ties break
        by highest slot id, so the choice is deterministic.  Returns the
        victim slot, or None when there is no candidate."""
        best = min(candidates, key=lambda c: (c[1], c[2], -c[0]),
                   default=None)
        return None if best is None else best[0]

    @property
    def total_free(self) -> int:
        return sum(self._free.values())

    def assign(self, queue: "AdmissionQueue",
               fits: Optional[Callable] = None,
               on_assign: Optional[Callable] = None,
               prefer: Optional[Callable] = None) -> List[tuple]:
        """Drain the queue into open slots; returns [(key, request)].

        Tightest-fit first: the engine with the fewest open slots is
        filled before emptier ones, so nearly-drained engines refill (and
        surplus clones go idle for the TTL reaper) instead of every engine
        hovering half-full.  Deterministic: ties break by insertion order.

        ``fits(key, request) -> bool`` (optional) is re-checked per
        assignment so engines can veto on resources beyond slot count —
        e.g. KV block commitments; a vetoing engine leaves this round.
        ``on_assign(key, request)`` (optional) runs *immediately* after
        each pop, before the next ``fits`` check — admission must happen
        here so resource checks see the commitments of earlier
        assignments in the same round, not stale pre-round state.
        ``prefer(key, request) -> float`` (optional) biases the engine
        choice for the head request: the highest-scoring engine wins and
        ties fall back to tightest-fit — prefix-affinity routing passes
        the clone's ``match_prefix`` depth here so same-prefix requests
        land where their blocks already live (ADR-009).
        """
        out = []
        while queue.depth > 0 and self._free:
            head = queue.peek()
            if prefer is None:
                key = min(self._free,
                          key=self._free.get)  # type: ignore[arg-type]
            else:
                key = min(self._free,
                          key=lambda k: (-prefer(k, head), self._free[k]))
            if fits is not None and not fits(key, head):
                del self._free[key]        # can't take the head request
                continue
            req = queue.take(1)[0]
            out.append((key, req))
            if on_assign is not None:
                on_assign(key, req)
            self._free[key] -= 1
            if self._free[key] <= 0:
                del self._free[key]
        return out




class PlacementEngine:
    """Cost/energy-aware clone-type selection for one demand bucket.

    Two decisions live here (ADR-004):

    ``required_type`` — the KV floor: walk the paper's escalation ladder
    (:meth:`ClonePool.escalate_type`, the §5.4 OutOfMemoryError flow) from
    the base tier until a tier's block pool can hold the request's
    prompt+window KV demand; a request that outgrows every tier degrades
    gracefully to the biggest fleet tier (preemption absorbs the squeeze)
    instead of raising.

    ``choose_type`` — among the tiers at or above the floor, rank by the
    policy's :func:`~repro.core.policy.placement_key` over a
    :class:`~repro.core.policy.Prediction` of (provisioning latency,
    chips-aware energy over the horizon, $ over the horizon); ties break
    to the smallest tier.  Urgent buckets (high-priority tenants) always
    rank by ``EXEC_TIME`` — a warm big clone beats booting a cheap one.
    """

    def __init__(self, pool: ClonePool, fleet: Optional[Sequence[str]] = None,
                 policy: Policy = Policy.EXEC_TIME_AND_ENERGY,
                 energy: Optional[TpuEnergyModel] = None):
        self.pool = pool
        self.policy = policy
        self.energy = energy or TpuEnergyModel()
        names = list(fleet) if fleet is not None else list(CLONE_TYPES)
        unknown = [n for n in names if n not in CLONE_TYPES]
        if unknown:
            raise ValueError(f"unknown clone types in fleet: {unknown}")
        self.fleet = sorted(set(names), key=lambda n: CLONE_TYPES[n].rank())
        # type -> demand buckets actually placed on it (recorded by the
        # FleetAutoscaler, not by speculative choose_type evaluations)
        self.decisions: Dict[str, int] = {}
        # cid -> usable-from time, shared by the FleetAutoscaler so a
        # clone resumed *this tick* is not mistaken for a warm one
        self.ready_at: Dict[int, float] = {}

    def chips(self, type_name: str) -> int:
        return chips_for(type_name, self.pool.tpu)

    def provision_pred(self, type_name: str) -> Prediction:
        """Marginal cost of putting one more work unit on this tier now.

        Time is the tier's provisioning latency given the pool's current
        inventory: an idle RUNNING secondary is available at its
        ``ready_at`` residue (0 when warm — a clone resumed this tick
        still carries its resume), a PAUSED one costs a resume, otherwise
        a cold boot.  Energy and $ are the tier's burn rates over the
        placement horizon (chips-aware)."""
        now = self.pool.clock()
        # open-breaker / dead clones are not capacity (ADR-006): placing
        # a bucket on them would dispatch into a tripped circuit
        idle = [max(0.0, self.ready_at.get(c.cid, 0.0) - now)
                for c in self.pool.running_secondaries(type_name)
                if not c.busy and c.serveable]
        paused = any(c.state is CloneState.PAUSED
                     and c.ctype.name == type_name and not c.is_primary
                     and c.serveable
                     for c in self.pool.clones)
        t = (min(idle) if idle
             else resume_time(1) if paused else BOOT_SECONDS)
        e = self.energy.busy_j(chips=self.chips(type_name),
                               seconds=PLACEMENT_HORIZON_S)
        usd = usd_per_second(type_name) * PLACEMENT_HORIZON_S
        return Prediction(time_s=t, energy_j=e, cost_usd=usd)

    def eligible(self, required_type: str) -> List[str]:
        """Fleet tiers at or above the required tier's rank."""
        rmin = CLONE_TYPES[required_type].rank()
        return [t for t in self.fleet if CLONE_TYPES[t].rank() >= rmin]

    def choose_type(self, required_type: str, *,
                    urgent: bool = False,
                    hint: Optional[str] = None,
                    affinity: Optional[Dict[str, int]] = None
                    ) -> Optional[str]:
        """The tier this bucket's capacity should be provisioned on.

        ``hint="spec_draft"`` picks the *cheapest adequate* tier by $-rate
        regardless of the fleet policy: a speculative-decoding draft clone
        (ADR-008) exists precisely to burn the cheap tier's cycles, so
        latency/energy scoring — which would happily pin the draft next to
        the verifier on premium — is overridden.

        ``hint="prefix_affinity"`` ranks by cached-prefix depth first
        (``affinity``: type -> deepest ``match_prefix`` token depth among
        that tier's live clones, supplied by the serving layer):
        re-prefilling tokens the fleet already holds is pure waste, so
        the deepest match wins, with the normal PLACEMENT_HORIZON policy
        key (provisioning latency / energy / $) breaking ties — which is
        also the full ranking for the zero-depth tiers.  A tier's depth
        only counts while it still has a *serveable* RUNNING clone: the
        cached blocks live on a specific clone, and if its breaker
        tripped (ADR-006) a fresh boot would come up with a cold pool, so
        the hint degrades to the plain policy ranking instead of chasing
        dead blocks.
        """
        cands = self.eligible(required_type)
        if not cands:
            return None
        if hint == "spec_draft":
            return min(cands, key=lambda t: (usd_per_second(t),
                                             CLONE_TYPES[t].rank()))
        policy = Policy.EXEC_TIME if urgent else self.policy
        if hint == "prefix_affinity" and affinity:
            def live_depth(t: str) -> int:
                alive = any(c.ctype.name == t and c.serveable
                            and c.state is CloneState.RUNNING
                            for c in self.pool.clones)
                return affinity.get(t, 0) if alive else 0
            return min(cands,
                       key=lambda t: (-live_depth(t),
                                      placement_key(policy,
                                                    self.provision_pred(t)),
                                      CLONE_TYPES[t].rank()))
        return min(cands,
                   key=lambda t: (placement_key(policy,
                                                self.provision_pred(t)),
                                  CLONE_TYPES[t].rank()))

    def required_type(self, base_type: str, blocks_needed: int,
                      real_blocks_of: Callable[[str], int]) -> str:
        """Smallest fleet tier (walking ``escalate_type`` from the base)
        whose block pool holds ``blocks_needed``; the biggest fleet tier
        when even the top of the ladder cannot (``escalate_type`` returns
        None — the caller degrades gracefully, ADR-004)."""
        fleet = set(self.fleet)
        t: Optional[str] = base_type
        last_fleet = base_type
        while t is not None:
            if t in fleet:
                last_fleet = t
                if real_blocks_of(t) >= blocks_needed:
                    return t
            t = self.pool.escalate_type(t)
        return last_fleet


class FleetAutoscaler:
    """Placement-driven elastic sizing of a heterogeneous secondary fleet.

    Demand arrives as buckets ``(required_type, urgent, work_units)`` —
    the Client Handler derives them per tenant/priority class and per
    KV-footprint tier.  Each bucket is placed on a tier by the
    :class:`PlacementEngine` (urgent buckets place first, then cheaper
    tiers), per-type targets are ``ceil(units / work_per_clone)`` under
    one global ``max_secondaries`` budget, and growth provisions through
    the pool (resume preferred over boot — costs land on the shared
    timeline via ``ready_at``).  Shrink is delegated to the pool's idle
    TTLs via ``reap_idle``: pause after ``PAUSE_IDLE_TTL``, power-off
    after ``OFF_IDLE_TTL``.
    """

    def __init__(self, pool: ClonePool, placement: PlacementEngine,
                 base_type: str = "main", work_per_clone: int = 1,
                 min_secondaries: int = 0, max_secondaries: int = 8):
        self.pool = pool
        self.placement = placement
        self.base_type = base_type
        self.work_per_clone = max(1, work_per_clone)
        self.min_secondaries = min_secondaries
        self.max_secondaries = max_secondaries
        # cid -> usable-from time; the dict is *shared* with the placement
        # engine so tier availability accounts for in-flight provisioning
        self.ready_at: Dict[int, float] = placement.ready_at
        self.peak_secondaries = 0
        self.scale_ups = 0
        self.samples: List[tuple] = []           # (t, running_secondaries)
        self.targets: Dict[str, int] = {}        # last tick's per-type target

    def clone_ready_delay(self, clone, now: float) -> float:
        """Seconds until ``clone`` is actually usable (0 if warm)."""
        return max(0.0, self.ready_at.get(clone.cid, 0.0) - now)

    def step(self, now: float, buckets: Sequence[tuple],
             in_flight: Optional[Dict[str, int]] = None) -> Dict[str, int]:
        """One control-loop tick; returns the per-type target sizes.

        ``buckets``: iterable of ``(required_type, urgent, work_units)``
        for the queued demand.  ``in_flight``: work units currently being
        served, per clone type — they hold their tier's capacity."""
        demand: Dict[str, int] = {}
        order: List[str] = []                    # budget-allocation order
        for rtype, urgent, units in sorted(
                buckets, key=lambda b: (not b[1], CLONE_TYPES[b[0]].rank())):
            t = self.placement.choose_type(rtype, urgent=urgent) or rtype
            self.placement.decisions[t] = \
                self.placement.decisions.get(t, 0) + 1
            demand[t] = demand.get(t, 0) + units
            if t not in order:
                order.append(t)
        for t, n in (in_flight or {}).items():
            demand[t] = demand.get(t, 0) + n
            if t not in order:
                order.append(t)
        if self.base_type not in order:
            order.append(self.base_type)
            demand.setdefault(self.base_type, 0)
        budget = self.max_secondaries
        self.targets = {}
        if self.min_secondaries > 0:     # warm base floor reserved FIRST,
            grant = min(self.min_secondaries, budget)   # never starved by
            self.targets[self.base_type] = grant        # other tiers
            budget -= grant
        for t in order:
            want = -(-demand[t] // self.work_per_clone)
            have = self.targets.get(t, 0)
            grant = max(0, min(want - have, budget))
            self.targets[t] = have + grant
            budget -= grant
        for t, target in self.targets.items():
            if target > len(self.pool.running_secondaries(t)):
                fresh, costs = self.pool.ensure_secondaries(t, target)
                for c, cost in zip(fresh, costs):
                    self.ready_at[c.cid] = now + cost
                if fresh:
                    self.scale_ups += 1
        total = len(self.pool.running_secondaries())
        if total > self.max_secondaries:
            # over cap (demand shifted tiers): pause idle surplus *per
            # type, over-target tiers first* — an untyped sweep would
            # pause the just-provisioned target tier and keep the stale
            # one, livelocking the shift until the idle TTL reaped it
            running_types = sorted(
                {c.ctype.name for c in self.pool.running_secondaries()},
                key=lambda t: (self.targets.get(t, 0),
                               CLONE_TYPES[t].rank()))
            for t in running_types:
                if total <= self.max_secondaries:
                    break
                total -= self.pool.pause_surplus(self.targets.get(t, 0), t)
        # shrink: TTL-driven (paper: idle secondaries are paused, then off)
        self.pool.reap_idle()
        running = len(self.pool.running_secondaries())
        self.peak_secondaries = max(self.peak_secondaries, running)
        self.samples.append((now, running))
        return dict(self.targets)
