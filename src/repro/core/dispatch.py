"""Dispatcher: non-blocking execution of remoteable work onto clones.

This is the execution half of the seed's ``ExecutionController`` split out
(the controller keeps the *decision* layer — predictions, policy, placement
— with unchanged semantics).  ``submit()`` issues work onto a clone and
returns a :class:`CloneTask` future immediately; the task's *completion* is
an event on the shared :class:`~repro.core.clock.VirtualClock`, so k
submissions genuinely overlap on the timeline: waiting for all of them
advances virtual time to ``max(done_at)``, not the sum.

Simulation honesty (DESIGN.md §2) is preserved: the callable runs eagerly
on the host to obtain its *value* and its measured-then-scaled venue
seconds; only the *latency* is played out on the virtual timeline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

from repro.core.clock import VirtualClock
from repro.core.clones import Clone, ClonePool


@dataclasses.dataclass(eq=False)          # identity semantics: usable as a key
class CloneTask:
    """Future-style handle for one unit of work issued onto a clone."""

    clone: Clone
    label: str = ""
    submitted_at: float = 0.0
    venue_seconds: float = 0.0     # modeled execution time on the clone
    extra_delay: float = 0.0       # provisioning / transfer charged up front
    done_at: float = 0.0           # submitted_at + extra_delay + venue_seconds
    done: bool = False
    cancelled: bool = False        # completion event revoked (ADR-006)
    value: object = None
    # the submitted work, kept so a hedged duplicate can re-issue the
    # exact closure on a second clone (the closure is pure — ADR-002)
    fn: Optional[Callable] = None
    fn_args: tuple = ()
    _event: object = None          # the clock completion Event
    _callbacks: List[Callable] = dataclasses.field(default_factory=list)

    @property
    def duration(self) -> float:
        """Total timeline seconds from submission to completion."""
        return self.done_at - self.submitted_at

    def add_done_callback(self, cb: Callable[["CloneTask"], None]) -> None:
        """Run ``cb(task)`` at completion (immediately if already done)."""
        if self.done:
            cb(self)
        else:
            self._callbacks.append(cb)

    def _complete(self) -> None:
        self.done = True
        for cb in self._callbacks:
            cb(self)
        self._callbacks.clear()


class Dispatcher:
    """Issues work onto clones; completions are virtual-clock events."""

    def __init__(self, pool: ClonePool, clock: VirtualClock):
        if not getattr(clock, "virtual", False):
            raise TypeError("Dispatcher needs a VirtualClock — overlap is "
                            "only well-defined on a simulated timeline")
        self.pool = pool
        self.clock = clock
        self.submitted = 0

    # ----------------------------------------------------------------- api
    def submit(self, clone: Clone, fn: Callable, args: Sequence = (),
               *, executor: Optional[Callable] = None,
               extra_delay: float = 0.0, label: str = "") -> CloneTask:
        """Run ``fn(*args)`` on ``clone``; returns immediately.

        ``executor(clone, fn, args) -> (value, venue_seconds)`` defaults to
        host execution scaled to the clone's venue (``Venue.execute``).
        ``extra_delay`` charges provisioning/transfer seconds that must
        elapse on the timeline before execution starts.
        """
        if executor is None:
            from repro.core.venues import Venue

            def executor(c, f, a):
                return Venue(c.spec).execute(f, *a)

        value, venue_s = executor(clone, fn, args)
        # fault-injected slowdowns (ADR-006) scale the modeled venue time
        # at the one choke point every dispatch passes through, so test
        # and benchmark executors stay fault-agnostic
        venue_s = float(venue_s) * max(1.0, getattr(clone, "slowdown", 1.0))
        task = CloneTask(clone=clone, label=label,
                         submitted_at=self.clock.now(),
                         venue_seconds=venue_s,
                         extra_delay=float(extra_delay),
                         fn=fn, fn_args=tuple(args))
        task.value = value
        task.done_at = task.submitted_at + task.extra_delay + task.venue_seconds
        task._event = self.clock.at(task.done_at, task._complete)
        self.submitted += 1
        return task

    def cancel(self, task: CloneTask) -> bool:
        """Revoke an in-flight task: its completion event never fires and
        its value is discarded (hedge losers, dispatches on dead clones).
        Returns False when the task already completed or was cancelled."""
        if task.done or task.cancelled:
            return False
        task.cancelled = True
        if task._event is not None:
            task._event.cancel()
        return True

    def wait(self, tasks: Sequence[CloneTask]) -> List[CloneTask]:
        """Advance the timeline until every task has completed."""
        for t in sorted(tasks, key=lambda t: t.done_at):
            if not t.done:
                self.clock.advance_to(t.done_at)
        return list(tasks)

    def wait_any(self, tasks: Sequence[CloneTask]) -> List[CloneTask]:
        """Advance until at least one of ``tasks`` completes; returns the
        completed subset."""
        live = [t for t in tasks if not t.done]
        if not live:
            return [t for t in tasks if t.done]
        first = min(live, key=lambda t: t.done_at)
        self.clock.advance_to(first.done_at)
        return [t for t in tasks if t.done]
