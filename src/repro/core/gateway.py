"""SLO-aware streaming gateway: admission, quotas, shedding, backpressure.

The serving fleet's front door (ADR-007).  ThinkAir's elasticity story
("millions of users", §5) has no defense when offered load exceeds what
the fleet can serve: the bounded :class:`~repro.core.scheduler.
AdmissionQueue` sheds blindly and everything admitted eventually misses
any latency target.  The :class:`StreamingGateway` sits *between*
arrivals and the Client Handler's queue and degrades gracefully instead,
following Phone2Cloud's deadline-aware offload decision: reject work that
cannot finish in time *up front*, rather than accepting it and failing
slowly.  Everything runs on the shared
:class:`~repro.core.clock.VirtualClock` — retries and quota refills are
deterministic timeline events, never wall-clock sleeps.

Pieces:

``TokenBucket`` / ``TenantPolicy``
    Per-tenant quota (tokens of *generated output* per virtual second,
    with a burst allowance) plus a fair-share ``weight``.  A tenant at
    its rate limit queues; it never starves the others.

``StreamingGateway.offer``
    The admission pipeline, in order: (1) an **exact-match LRU response
    cache** short-circuits duplicate prompts — a hit synthesizes the
    completion at the gateway, costing zero fleet work; (2) **predictive
    admission**: a request carrying a deadline is rejected immediately
    when its estimated completion time — link transfer
    (:class:`~repro.core.profilers.NetworkProfiler`, so a 3g client gets
    an honest earlier rejection than a wifi-local one) + backlog drain at
    the observed TPOT + its own decode time — exceeds the deadline;
    (3) **bounded-backlog load shedding**: past the backlog bound the
    lowest-priority *batch* request is shed (the incoming request can be
    its own victim); interactive work is never shed.

``StreamingGateway.release``
    Weighted fair-share dequeueing into the handler's admission queue:
    **deficit round-robin** across per-tenant queues (each rotation
    grants ``quantum x weight`` deficit; a release costs the request's
    token cost), gated by the tenant's token bucket.  Within a tenant the
    queue is **deadline-ordered** (interactive/EDF first, then batch in
    arrival order).

Backpressure: a shed *deadline-less* request is replayed after a
**deterministic jittered exponential backoff** (seeded per (rid,
attempt), scheduled as a clock event) up to ``retry_max`` attempts —
the virtual analogue of HTTP 503 + Retry-After.  Deadline-carrying work
is never retried: its deadline is fixed at arrival, so a request the
estimator already judged infeasible stays infeasible.

Fleet-capacity feedback (ADR-006 -> ADR-007): the handler reports
``observe_fleet(healthy, total, slots)`` every scheduler round — DEAD
clones and open breakers shrink both the estimator's service rate and
the backlog bound — and a
:class:`~repro.core.faults.FaultInjector` ``on_fire`` hook tightens
admission the instant a clone dies, before the next round's census.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from bisect import insort
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.clock import ensure_clock
from repro.core.profilers import NetworkProfiler
from repro.core.scheduler import ServeCompletion, ServeRequest

SLO_CLASSES = ("interactive", "batch")


class TokenBucket:
    """Continuous-refill token bucket on virtual time.

    ``rate`` is tokens per virtual second, ``burst`` the bucket depth
    (default: one second of rate).  The bucket starts full.  ``eta``
    reports the absolute time a ``take`` of the given cost will succeed
    — the gateway schedules its next release around it instead of
    polling."""

    def __init__(self, rate: float = math.inf,
                 burst: Optional[float] = None):
        if rate <= 0:
            raise ValueError(f"token-bucket rate must be > 0: {rate}")
        self.rate = float(rate)
        if burst is None:
            burst = rate if math.isfinite(rate) else math.inf
        self.burst = float(burst)
        self.tokens = self.burst
        self._t = 0.0

    def _refill(self, now: float) -> None:
        if now > self._t:
            if math.isfinite(self.rate):
                self.tokens = min(self.burst,
                                  self.tokens + (now - self._t) * self.rate)
            self._t = now

    def take(self, now: float, cost: float) -> bool:
        """Consume ``cost`` tokens if available right now."""
        self._refill(now)
        if self.tokens + 1e-9 >= cost:
            self.tokens = min(self.tokens - cost, self.burst)
            return True
        return False

    def eta(self, now: float, cost: float) -> float:
        """Earliest time a ``take(cost)`` will succeed (== now if it
        would succeed already)."""
        self._refill(now)
        if self.tokens + 1e-9 >= cost:
            return now
        return now + (cost - self.tokens) / self.rate


@dataclasses.dataclass
class TenantPolicy:
    """Per-tenant quota + fair-share weight.

    ``rate``/``burst`` bound the tenant's *output-token* throughput
    (``math.inf`` = unmetered); ``weight`` scales its deficit-round-robin
    share of contended release capacity."""

    weight: float = 1.0
    rate: float = math.inf
    burst: Optional[float] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be > 0: {self.weight}")


class ResponseCache:
    """Exact-match LRU response cache (prompt bytes + token budget ->
    generated tokens).  Greedy decoding is deterministic, so an exact
    prompt repeat *is* the same response — the gateway serves it without
    touching the fleet."""

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._d: "OrderedDict[tuple, List[int]]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(req: ServeRequest) -> tuple:
        p = np.asarray(req.prompt)
        return (p.tobytes(), int(p.size), int(req.max_new_tokens))

    def get(self, req: ServeRequest) -> Optional[List[int]]:
        k = self.key(req)
        toks = self._d.get(k)
        if toks is None:
            self.misses += 1
            return None
        self._d.move_to_end(k)
        self.hits += 1
        return list(toks)

    def put(self, req: ServeRequest, tokens: List[int]) -> None:
        if self.max_entries <= 0:
            return
        k = self.key(req)
        self._d[k] = list(tokens)
        self._d.move_to_end(k)
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)

    def __len__(self) -> int:
        return len(self._d)


class AdmissionEstimator:
    """Completion-time estimator behind predictive admission.

    Tracks the fleet's observed time-per-output-token as an EMA (seeded
    with ``tpot0`` until the first completion reports) and converts the
    current backlog into an expected queueing delay:
    ``backlog_tokens x tpot / service_slots``, inflated by
    ``1 / healthy_frac`` during fault-induced capacity loss so admission
    tightens exactly when breakers open (ADR-006 signal)."""

    def __init__(self, tpot0: float = 0.05, alpha: float = 0.35):
        self.tpot_s = float(tpot0)
        self.alpha = alpha
        self.samples = 0

    def observe(self, tpot_s: float) -> None:
        if tpot_s <= 0:
            return
        self.tpot_s += self.alpha * (tpot_s - self.tpot_s)
        self.samples += 1

    def wait_s(self, backlog_tokens: float, slots: int,
               healthy_frac: float) -> float:
        return (backlog_tokens * self.tpot_s / max(1, slots)
                / max(healthy_frac, 1e-3))

    def service_s(self, new_tokens: int) -> float:
        return new_tokens * self.tpot_s


class StreamingGateway:
    """SLO-aware front door between arrivals and the Client Handler.

    Construct with the serving timeline's clock (or let
    :meth:`adopt_clock` bind it when the handler takes the gateway).
    ``tenants`` maps tenant name -> :class:`TenantPolicy`; requests from
    unknown tenants (or ``tenant=None``) use ``default_policy``.
    ``max_backlog_tokens`` bounds the *queued* output-token backlog —
    beyond it batch work is shed; the bound shrinks with fleet health.
    """

    def __init__(self, *, clock=None,
                 tenants: Optional[Dict[str, TenantPolicy]] = None,
                 default_policy: Optional[TenantPolicy] = None,
                 max_backlog_tokens: float = 512.0,
                 quantum: float = 16.0,
                 link: str = "wifi-local",
                 net: Optional[NetworkProfiler] = None,
                 retry_base_s: float = 0.5, retry_max: int = 3,
                 retry_jitter: float = 0.5,
                 cache_entries: int = 64,
                 tpot0: float = 0.05,
                 seed: int = 0):
        self.clock = None
        if clock is not None:
            self.adopt_clock(clock)
        self.policies: Dict[str, TenantPolicy] = dict(tenants or {})
        self.default_policy = default_policy or TenantPolicy()
        self.max_backlog_tokens = float(max_backlog_tokens)
        self.quantum = float(quantum)
        self.net = net or NetworkProfiler(link)
        self.retry_base_s = retry_base_s
        self.retry_max = retry_max
        self.retry_jitter = retry_jitter
        self.cache = ResponseCache(cache_entries)
        self.estimator = AdmissionEstimator(tpot0=tpot0)
        self.seed = seed
        # per-tenant EDF queues + DRR state
        self._queues: Dict[str, List[ServeRequest]] = {}
        self._rr: List[str] = []                   # rotation order
        self._deficit: Dict[str, float] = {}
        self._buckets: Dict[str, TokenBucket] = {}
        self._queued_tokens = 0.0
        self._inflight_tokens = 0.0
        self._released: Dict[int, ServeRequest] = {}
        # fleet-capacity signal (ADR-006): healthy/total serveable clones
        # + decode slots, refreshed by the handler each round; on_fire
        # faults tighten it immediately until the next census
        self._healthy = 1
        self._total = 1
        self._slots = 1
        self._fault_pressure = 0
        # backpressure (Retry-After) state
        self._retry_events: List[object] = []
        self._pending_retries = 0
        self._bucket_next: Optional[float] = None
        self._cached_out: List[ServeCompletion] = []
        # telemetry
        self.offered = 0
        self.admitted = 0
        self.cache_hits = 0
        self.rejected = 0
        self.shed = 0
        self.retries = 0
        self.dropped = 0
        self.expired = 0
        self.fault_signals = 0
        self.shed_by_slo: Dict[str, int] = {}
        self.rejected_by_slo: Dict[str, int] = {}
        self.retry_log: List[Tuple[int, int, float]] = []

    # ------------------------------------------------------------- plumbing
    def adopt_clock(self, clock) -> None:
        """Bind the serving timeline (idempotent; disagreement raises)."""
        clock = ensure_clock(clock)
        if not getattr(clock, "virtual", False):
            raise TypeError("StreamingGateway schedules retry events — it "
                            "needs a VirtualClock")
        if self.clock is not None and self.clock is not clock:
            raise ValueError("gateway already bound to a different clock")
        self.clock = clock

    def policy(self, tenant: Optional[str]) -> TenantPolicy:
        return self.policies.get(tenant or "", self.default_policy) \
            if tenant is not None else self.default_policy

    def _bucket(self, tenant: str) -> TokenBucket:
        b = self._buckets.get(tenant)
        if b is None:
            pol = self.policy(tenant)
            b = self._buckets[tenant] = TokenBucket(pol.rate, pol.burst)
        return b

    @staticmethod
    def cost(req: ServeRequest) -> int:
        """A request's service cost in output tokens (the unit quotas,
        the backlog bound, and DRR deficits are all denominated in)."""
        return max(1, int(req.max_new_tokens))

    @staticmethod
    def _order_key(req: ServeRequest) -> tuple:
        """Deadline-ordered admission within a tenant: interactive (EDF)
        ahead of batch, then earliest absolute deadline, then FIFO."""
        dl = (req.arrival_t + req.deadline_s
              if req.deadline_s is not None else math.inf)
        return (req.slo != "interactive", dl, req.arrival_t, req.rid)

    # ----------------------------------------------------- capacity signal
    def observe_fleet(self, healthy: int, total: int, slots: int) -> None:
        """Per-round fleet census: serveable vs total clones and the
        decode slots the healthy set offers.  Resets any interim
        ``note_fault`` pressure (the census supersedes it)."""
        self._healthy = max(0, int(healthy))
        self._total = max(1, int(total))
        self._slots = max(1, int(slots))
        self._fault_pressure = 0

    def note_fault(self, clone=None, fault=None) -> None:
        """FaultInjector ``on_fire`` hook: a clone just died — count it
        against the healthy set *now*, before the next round's census,
        so admission tightens at the fault instant."""
        self._fault_pressure += 1
        self.fault_signals += 1

    def healthy_frac(self) -> float:
        healthy = min(max(self._healthy - self._fault_pressure, 0),
                      self._total)
        return max(healthy / self._total, 0.05)

    # ----------------------------------------------------------- admission
    def backlog_tokens(self, ahead_of: Optional[ServeRequest] = None
                       ) -> float:
        """Output tokens queued at the gateway plus released-but-unserved
        in-flight work — what a new arrival queues behind.  With
        ``ahead_of``, only queued work that would be released before it
        counts (release is class-priority: batch never delays an
        interactive request at the gateway)."""
        queued = self._queued_tokens
        if ahead_of is not None and ahead_of.slo == "interactive":
            queued = float(sum(self.cost(r) for q in self._queues.values()
                               for r in q if r.slo == "interactive"))
        # released work is on average half-served (continuous batching
        # starts a newcomer as soon as ONE slot frees, not when the whole
        # in-flight cohort drains) — count it at half weight
        return queued + 0.5 * self._inflight_tokens

    def estimate_done(self, req: ServeRequest, now: float) -> float:
        """Predicted completion time for ``req`` admitted now: link
        transfer (prompt up + tokens down, honest per link profile) +
        backlog drain at observed TPOT + its own decode time."""
        nbytes = int(np.asarray(req.prompt).nbytes + 8 * req.max_new_tokens)
        xfer = self.net.transfer_time(nbytes)
        wait = self.estimator.wait_s(
            self.backlog_tokens(ahead_of=req) + self.cost(req),
            self._slots, self.healthy_frac())
        return now + xfer + wait + self.estimator.service_s(
            req.max_new_tokens)

    def offer(self, req: ServeRequest, now: float) -> str:
        """Admission pipeline; returns one of ``"cached"``, ``"queued"``,
        ``"rejected"``, ``"shed"`` (see the module docstring for the
        order and semantics)."""
        self.offered += 1
        toks = self.cache.get(req)
        if toks is not None:
            self.cache_hits += 1
            self._cached_out.append(ServeCompletion(
                req.rid, toks, req.arrival_t, now, now, "gateway-cache",
                tenant=req.tenant, slo=req.slo, deadline_s=req.deadline_s,
                token_ts=[now] * len(toks), cached=True))
            return "cached"
        if req.deadline_s is not None:
            est = self.estimate_done(req, now)
            if est - req.arrival_t > req.deadline_s:
                self._count(self.rejected_by_slo, req.slo)
                self.rejected += 1
                return "rejected"
        c = self.cost(req)
        bound = self.max_backlog_tokens * self.healthy_frac()
        if self._queued_tokens + c > bound:
            victim = self._shed_victim(req)
            if victim is req:
                self._shed(req, now)
                return "shed"
            if victim is not None:
                self._queues[victim.tenant or ""].remove(victim)
                self._queued_tokens -= self.cost(victim)
                self._shed(victim, now)
            # interactive overflow with no batch victim left: admit — the
            # predictive check above already rejected infeasible deadlines
        self._enqueue(req, now)
        return "queued"

    def _count(self, d: Dict[str, int], slo: str) -> None:
        d[slo] = d.get(slo, 0) + 1

    def _enqueue(self, req: ServeRequest, now: float) -> None:
        t = req.tenant or ""
        q = self._queues.get(t)
        if q is None:
            q = self._queues[t] = []
            self._rr.append(t)
            self._deficit.setdefault(t, 0.0)
        insort(q, req, key=self._order_key)
        self._queued_tokens += self.cost(req)

    def _shed_victim(self, incoming: ServeRequest
                     ) -> Optional[ServeRequest]:
        """The request bounded-backlog shedding evicts: the *batch*-class
        request with the lowest priority, breaking ties toward the newest
        arrival (it has waited least).  The incoming request competes on
        the same terms.  Interactive work is never a victim; ``None``
        means nothing batch is queued and the incoming request is
        interactive."""
        cands = [r for q in self._queues.values() for r in q
                 if r.slo != "interactive"]
        if incoming.slo != "interactive":
            cands.append(incoming)
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority, -r.arrival_t, -r.rid))

    # -------------------------------------------------------- backpressure
    def _shed(self, req: ServeRequest, now: float) -> None:
        self.shed += 1
        self._count(self.shed_by_slo, req.slo)
        if req.deadline_s is not None:
            return                   # deadline fixed at arrival: no retry
        attempt = req.retries + 1
        if attempt > self.retry_max:
            self.dropped += 1
            return
        req.retries = attempt
        # deterministic jittered exponential backoff: the jitter draw is
        # keyed on (seed, rid, attempt), so one request's retry timeline
        # is identical across runs — replayable backpressure
        jit = float(np.random.default_rng(
            (self.seed, req.rid, attempt)).random())
        delay = (self.retry_base_s * (2.0 ** (attempt - 1))
                 * (1.0 + self.retry_jitter * jit))
        self.retries += 1
        self._pending_retries += 1
        self.retry_log.append((req.rid, attempt, now + delay))
        self._retry_events.append(
            self.clock.schedule(delay, functools.partial(self._reoffer,
                                                         req)))

    def _reoffer(self, req: ServeRequest) -> None:
        self._pending_retries -= 1
        self.offer(req, self.clock.now())

    # -------------------------------------------------------------- release
    def release(self, now: float, queue, budget: int) -> int:
        """Deficit-round-robin dequeue into the handler's admission
        queue, at most ``budget`` requests.  Two class-priority phases —
        every tenant's *interactive* heads drain before anyone's batch
        work, so a burst of batch arrivals never delays interactive
        release.  Within a phase, each rotation grants every backlogged
        tenant ``quantum x weight`` deficit; releasing a request costs
        its token cost and must pass the tenant's token bucket (a
        blocked head parks the tenant until its bucket's ``eta``,
        surfaced via :meth:`next_event_time`).  Expired deadlines are
        dropped here rather than served dead."""
        self._bucket_next = None
        released = self._release_phase(now, queue, budget, "interactive")
        released += self._release_phase(now, queue, budget - released,
                                        None)
        return released

    def _release_phase(self, now: float, queue, budget: int,
                       only_slo: Optional[str]) -> int:
        released = 0
        while released < budget:
            advanced = False
            needs_deficit = False
            for t in list(self._rr):
                q = self._queues.get(t)
                if not q:
                    self._deficit[t] = 0.0
                    continue
                self._deficit[t] += self.quantum * self.policy(t).weight
                bucket = self._bucket(t)
                while q and released < budget:
                    head = q[0]
                    if only_slo is not None and head.slo != only_slo:
                        break    # EDF order: nothing of this class left
                    if (head.deadline_s is not None
                            and now - head.arrival_t > head.deadline_s):
                        q.pop(0)
                        self._queued_tokens -= self.cost(head)
                        self.expired += 1
                        self.rejected += 1
                        self._count(self.rejected_by_slo, head.slo)
                        continue
                    c = self.cost(head)
                    if self._deficit[t] < c:
                        needs_deficit = True
                        break
                    if not bucket.take(now, c):
                        self._note_event(max(bucket.eta(now, c),
                                             now + 1e-9))
                        break
                    q.pop(0)
                    self._deficit[t] -= c
                    self._queued_tokens -= c
                    queue.offer(head, now)
                    self._released[head.rid] = head
                    self._inflight_tokens += c
                    self.admitted += 1
                    released += 1
                    advanced = True
                if not q:
                    self._deficit[t] = 0.0
            if released >= budget or not (advanced or needs_deficit):
                break
        return released

    def _note_event(self, t: float) -> None:
        if self._bucket_next is None or t < self._bucket_next:
            self._bucket_next = t

    # ------------------------------------------------------------ feedback
    def observe_completion(self, c: ServeCompletion) -> None:
        """Fold a served completion back: release its in-flight tokens,
        feed the TPOT estimator, and populate the response cache."""
        if c.cached:
            return
        req = self._released.pop(c.rid, None)
        if req is None:
            return
        self._inflight_tokens = max(
            0.0, self._inflight_tokens - self.cost(req))
        tpot = c.tpot_s
        if tpot > 0:
            self.estimator.observe(tpot)
        self.cache.put(req, list(map(int, c.tokens)))

    def drain_cached(self) -> List[ServeCompletion]:
        out, self._cached_out = self._cached_out, []
        return out

    # ------------------------------------------------------------- queries
    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def pending(self) -> int:
        """Work the gateway still owes the serving loop: queued requests
        plus scheduled Retry-After replays."""
        return self.queued + self._pending_retries

    def next_event_time(self) -> Optional[float]:
        """Earliest time the gateway can make progress it cannot make
        now: a scheduled retry replay or a quota-blocked head's bucket
        eta.  The serving loop bounds its idle waits on this."""
        times = [ev.time for ev in self._retry_events
                 if not ev.fired and not ev.cancelled]
        if self._bucket_next is not None:
            times.append(self._bucket_next)
        return min(times) if times else None

    def stats(self) -> Dict[str, float]:
        return {
            "offered": self.offered, "admitted": self.admitted,
            "cache_hits": self.cache_hits, "rejected": self.rejected,
            "shed": self.shed, "retries": self.retries,
            "dropped": self.dropped, "expired": self.expired,
            "queued": self.queued, "tpot_ema_s": self.estimator.tpot_s,
        }
