"""Offloading policies (paper §4.3): None / ExecutionTime / Energy / Both."""
from __future__ import annotations

import dataclasses
import enum


class Policy(enum.Enum):
    NONE = "none"
    EXEC_TIME = "exec_time"
    ENERGY = "energy"
    EXEC_TIME_AND_ENERGY = "exec_time_and_energy"


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Predicted cost of one placement choice."""
    time_s: float
    energy_j: float


def should_offload(policy: Policy, local: Prediction,
                   remote: Prediction) -> bool:
    """Paper semantics: offload only if the policy's objective(s) improve."""
    if policy is Policy.NONE:
        return False
    if policy is Policy.EXEC_TIME:
        return remote.time_s < local.time_s
    if policy is Policy.ENERGY:
        return remote.energy_j < local.energy_j
    return (remote.time_s < local.time_s
            and remote.energy_j < local.energy_j)
