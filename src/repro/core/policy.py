"""Offloading policies (paper §4.3): None / ExecutionTime / Energy / Both.

Extended (ADR-004) into a *placement* scorer for the heterogeneous fleet:
``Prediction`` carries a $-cost alongside time and energy, and
``placement_key`` turns a policy into a total order over placement
candidates (clone-type tiers).  ``should_offload`` keeps the paper's exact
offload semantics; the fleet autoscaler ranks with ``placement_key``.
"""
from __future__ import annotations

import dataclasses
import enum


class Policy(enum.Enum):
    NONE = "none"
    EXEC_TIME = "exec_time"
    ENERGY = "energy"
    EXEC_TIME_AND_ENERGY = "exec_time_and_energy"


# Nominal service horizon (s): once placed, a work unit occupies its venue
# for about this long beyond the venue's availability latency.  The
# energy-delay ranking adds it to ``time_s`` so a warm-but-power-hungry
# tier does not degenerate to a free win (0 x anything == 0); rankings on
# fixed rates are otherwise horizon-invariant.
PLACEMENT_HORIZON_S = 60.0


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Predicted cost of one placement choice.

    ``cost_usd`` is the on-demand $ of the choice over the placement
    horizon (0 for the offload path, which compares phone vs cloud where
    the paper bills no per-request price)."""
    time_s: float
    energy_j: float
    cost_usd: float = 0.0


def should_offload(policy: Policy, local: Prediction,
                   remote: Prediction) -> bool:
    """Paper semantics: offload only if the policy's objective(s) improve."""
    if policy is Policy.NONE:
        return False
    if policy is Policy.EXEC_TIME:
        return remote.time_s < local.time_s
    if policy is Policy.ENERGY:
        return remote.energy_j < local.energy_j
    return (remote.time_s < local.time_s
            and remote.energy_j < local.energy_j)


def placement_key(policy: Policy, pred: Prediction) -> tuple:
    """Total order over fleet placement candidates (lower is better).

    The policy names the primary objective; the remaining quantities
    break ties, so the order is always total:

    - ``NONE`` — no offload objective exists, so placement ranks purely
      by $-cost (cheapest adequate tier wins), then time, then energy.
    - ``EXEC_TIME`` — provisioning latency first (a RUNNING tier beats a
      paused one beats a cold boot), then $, then energy.
    - ``ENERGY`` — energy rate first, then $, then time.
    - ``EXEC_TIME_AND_ENERGY`` — the energy-delay product (scale-free
      combination of both objectives) over the horizon-inclusive delay,
      $ tie-break.
    """
    if policy is Policy.NONE:
        return (pred.cost_usd, pred.time_s, pred.energy_j)
    if policy is Policy.EXEC_TIME:
        return (pred.time_s, pred.cost_usd, pred.energy_j)
    if policy is Policy.ENERGY:
        return (pred.energy_j, pred.cost_usd, pred.time_s)
    return ((pred.time_s + PLACEMENT_HORIZON_S) * pred.energy_j,
            pred.cost_usd, pred.time_s)
