"""The programmer API (paper §4.1): ``@remote`` marks a method offloadable.

The paper's toolchain (Remoteable base class + @Remote annotation + code
generator emitting reflection wrappers) collapses, in JAX, to a decorator:
the wrapped callable is pure, its arguments are pytrees (the serializable
state), and the generated "localGenerate + controller.execute" indirection is
the returned wrapper.  ``copyState`` is unnecessary — results are the only
mutated state and flow back functionally.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax


@dataclasses.dataclass
class RemoteableMethod:
    """Registered offloadable method + its ThinkAir metadata."""

    name: str
    fn: Callable                                   # pure function of pytrees
    size_fn: Callable[..., float] = None           # input-size proxy
    split_fn: Optional[Callable] = None            # (args, k) -> [shard_args]
    merge_fn: Optional[Callable] = None            # [shard_results] -> result
    mem_fn: Optional[Callable[..., int]] = None    # working-set bytes
    jit: bool = True
    static_args: tuple = ()                        # shape-determining args
    _jitted: Optional[Callable] = None

    def callable(self) -> Callable:
        if not self.jit:
            return self.fn
        if self._jitted is None:
            self._jitted = jax.jit(self.fn, static_argnums=self.static_args)
        return self._jitted

    def size_key(self, *args, **kw) -> float:
        if self.size_fn is not None:
            return float(self.size_fn(*args, **kw))
        from repro.core.venues import pytree_bytes
        return float(pytree_bytes((args, kw)))

    @property
    def parallelizable(self) -> bool:
        return self.split_fn is not None and self.merge_fn is not None


REGISTRY: Dict[str, RemoteableMethod] = {}

_DEFAULT_CONTROLLER = None


def set_default_controller(controller) -> None:
    global _DEFAULT_CONTROLLER
    _DEFAULT_CONTROLLER = controller


def get_default_controller():
    return _DEFAULT_CONTROLLER


def remote(fn: Callable = None, *, size: Callable = None,
           split: Callable = None, merge: Callable = None,
           mem: Callable = None, jit: bool = True, name: str = None):
    """Decorator: register ``fn`` as remoteable and route calls through the
    ambient ExecutionController (transparent offloading, paper §4.4)."""

    def wrap(f: Callable):
        rm = RemoteableMethod(name or f.__name__, f, size_fn=size,
                              split_fn=split, merge_fn=merge, mem_fn=mem,
                              jit=jit)
        REGISTRY[rm.name] = rm

        def wrapper(*args, **kw):
            ec = get_default_controller()
            if ec is None:                     # no framework: plain local call
                return rm.callable()(*args, **kw)
            return ec.execute(rm, *args, **kw).value

        wrapper.remoteable = rm
        wrapper.__name__ = rm.name
        return wrapper

    return wrap(fn) if fn is not None else wrap
