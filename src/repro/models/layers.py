"""Shared neural layers: norms, RoPE, MLP, and reference (pure-XLA) attention.

The attention here is the *reference path* used for dry-run/roofline lowering
and CPU execution; the Pallas flash-attention kernel (``repro.kernels``) is the
TPU hot path and is validated against :func:`attention_xla` in tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# Query-chunk size above which the reference attention switches to a scanned,
# memory-bounded formulation (keeps 32k-prefill activation memory O(S*chunk)).
_Q_CHUNK = 1024


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dtype)


def apply_norm(x: jax.Array, params: dict, norm_type: str) -> jax.Array:
    if norm_type == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


# --------------------------------------------------------------------------- #
# Rotary position embedding (llama-style rotate-half)
# --------------------------------------------------------------------------- #
def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """positions: (..., S) int32 -> cos/sin tables (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B?, S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :].astype(jnp.float32)
    sin = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #
_ACTS = {
    "silu": jax.nn.silu,
    "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
}


def mlp(params: dict, x: jax.Array, act: str, gated: bool) -> jax.Array:
    fn = _ACTS[act]
    h = jnp.einsum("bsd,df->bsf", x, params["wi"],
                   preferred_element_type=jnp.float32)
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, params["wg"],
                       preferred_element_type=jnp.float32)
        h = fn(g) * h
    else:
        h = fn(h)
    h = h.astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Attention masks
# --------------------------------------------------------------------------- #
def attn_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
              window: Optional[int], prefix_len: int = 0) -> jax.Array:
    """Boolean allow-mask (…, Sq, Sk) from absolute positions."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if causal:
        ok = k <= q
        if prefix_len:
            ok = ok | ((q < prefix_len) & (k < prefix_len))
    else:
        ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if window is not None:
        ok = ok & (k > q - window)
    # unwritten ring-buffer slots carry negative positions -> invalid
    ok = ok & (k >= 0)
    return ok


# --------------------------------------------------------------------------- #
# Reference attention (GQA, causal / sliding-window / prefix-LM, softcap)
# --------------------------------------------------------------------------- #
def _attn_core(q, k, v, q_pos, k_pos, *, causal, window, prefix_len, softcap):
    """q: (B,Sq,Hkv,G,D); k,v: (B,Sk,Hkv,D) -> (B,Sq,Hkv,G,D)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = attn_mask(q_pos, k_pos, causal=causal, window=window,
                     prefix_len=prefix_len)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def _attn_core_m(q, k, v, q_pos, k_pos, *, causal, window, prefix_len,
                 softcap):
    """Shard-aware core: q (B,M,Sq,Hkv,G,D) with M a *sharded* q-row block
    dim; k,v (B,Sk,Hkv,D) broadcast across M (no copy)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bmqhgd,bkhd->bmhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = attn_mask(q_pos, k_pos[:, None], causal=causal, window=window,
                     prefix_len=prefix_len)             # (B, M, Sq, Sk)
    scores = jnp.where(mask[:, :, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bmhgqk,bkhd->bmqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  prefix_len: int = 0, softcap: Optional[float] = None,
                  q_offset=0, k_pos: Optional[jax.Array] = None,
                  q_chunk: int = _Q_CHUNK, seq_shards: int = 1,
                  constrain_cb=None, unroll_chunks: bool = False) -> jax.Array:
    """Grouped-query attention, memory-bounded via query chunking.

    q: (B, Sq, Hq, D);  k, v: (B, Sk, Hkv, D);  returns (B, Sq, Hq, D).
    ``q_offset`` is the absolute position of q[0] (decode: the cache cursor).
    ``k_pos`` overrides key absolute positions (ring buffers).
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    if jnp.ndim(q_offset) == 0:
        q_pos_all = jnp.broadcast_to(q_offset + jnp.arange(sq), (b, sq))
    else:
        q_pos_all = q_offset[:, None] + jnp.arange(sq)[None]

    core = functools.partial(_attn_core, causal=causal, window=window,
                             prefix_len=prefix_len, softcap=softcap)

    def map_chunks(f, xs, n):
        # lax.map lowers to a while loop whose body XLA cost analysis counts
        # once; analysis lowerings unroll so every chunk's FLOPs are visible
        if unroll_chunks:
            ys = [f(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
            return jnp.stack(ys)
        return jax.lax.map(f, xs)

    if seq_shards > 1 and sq % seq_shards == 0:
        # sequence-parallel path: q rows regrouped (B, M, rows) with M the
        # sharded block dim — the inner chunk loop (lax.map is sequential,
        # its loop dim can never shard) keeps M intact so GSPMD tiles the
        # score tensor instead of replicating it over the model axis
        m = seq_shards
        rows = sq // m
        qm = qg.reshape(b, m, rows, hkv, g, d)
        qpm = q_pos_all.reshape(b, m, rows)
        if constrain_cb is not None:
            qm = constrain_cb(qm)
        core_m = functools.partial(_attn_core_m, causal=causal,
                                   window=window, prefix_len=prefix_len,
                                   softcap=softcap)
        # per-device score tile parity with the heads-TP path: all heads
        # live on every shard here, so the row chunk shrinks by seq_shards
        ic = min(max(128, q_chunk // seq_shards), rows)
        if rows > ic and rows % ic == 0:
            n = rows // ic
            qc = jnp.moveaxis(qm.reshape(b, m, n, ic, hkv, g, d), 2, 0)
            qpc = jnp.moveaxis(qpm.reshape(b, m, n, ic), 2, 0)

            def chunk_fn_m(args):
                qi, qpi = args
                if constrain_cb is not None:
                    qi = constrain_cb(qi)
                return core_m(qi, k, v, qpi, k_pos)

            out = map_chunks(jax.checkpoint(chunk_fn_m), (qc, qpc), n)
            out = jnp.moveaxis(out, 0, 2)              # (B, M, n, ic, ...)
            out = out.reshape(b, sq, hkv, g, d)
        else:
            out = core_m(qm, k, v, qpm, k_pos).reshape(b, sq, hkv, g, d)
        return out.reshape(b, sq, hq, d)

    if sq > q_chunk and sq % q_chunk == 0:
        n = sq // q_chunk
        qg_c = qg.reshape(b, n, q_chunk, hkv, g, d).swapaxes(0, 1)
        qp_c = q_pos_all.reshape(b, n, q_chunk).swapaxes(0, 1)
        # checkpoint: scores/probs are recomputed in backward instead of
        # being stacked across chunks as scan residuals (flash-attention
        # memory semantics for the XLA reference path)
        chunk_fn = jax.checkpoint(
            lambda args: core(args[0], k, v, args[1], k_pos))
        out = map_chunks(chunk_fn, (qg_c, qp_c), n)
        out = out.swapaxes(0, 1).reshape(b, sq, hkv, g, d)
    else:
        out = core(qg, k, v, q_pos_all, k_pos)
    return out.reshape(b, sq, hq, d)
