"""Residual blocks: attention / RG-LRU / RWKV, each with norm + FFN."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rglru, rwkv6
from repro.models.context import RunContext
from repro.models.layers import apply_norm, apply_rope, attention_xla, mlp
from repro.models.moe import moe_apply, moe_specs
from repro.models.spec import ParamSpec


# --------------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------------- #
def norm_specs(cfg: ModelConfig):
    d = cfg.d_model
    if cfg.norm_type == "rmsnorm":
        return {"scale": ParamSpec((d,), ("embed",), init="zeros")}
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def mlp_specs(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    sp = {"wi": ParamSpec((d, f), ("embed", "mlp")),
          "wo": ParamSpec((f, d), ("mlp", "embed"), fan_in=f)}
    if cfg.mlp_gated:
        sp["wg"] = ParamSpec((d, f), ("embed", "mlp"))
    return sp


def attn_specs(cfg: ModelConfig):
    d, hq, hkv, n = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sp = {
        "wq": ParamSpec((d, hq, n), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, hkv, n), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, hkv, n), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((hq, n, d), ("heads", "head_dim", "embed"),
                        fan_in=hq * n),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec((hq, n), ("heads", "head_dim"), init="zeros")
        sp["bk"] = ParamSpec((hkv, n), ("kv_heads", "head_dim"), init="zeros")
        sp["bv"] = ParamSpec((hkv, n), ("kv_heads", "head_dim"), init="zeros")
    return sp


def block_specs(cfg: ModelConfig, kind: str):
    sp = {"norm1": norm_specs(cfg), "norm2": norm_specs(cfg)}
    if kind == "attn":
        sp["attn"] = attn_specs(cfg)
        sp["ffn"] = moe_specs(cfg) if cfg.is_moe else mlp_specs(cfg)
    elif kind == "rglru":
        sp["rec"] = rglru.rglru_specs(cfg)
        sp["ffn"] = mlp_specs(cfg)
    elif kind == "rwkv":
        sp["tm"] = rwkv6.rwkv_time_specs(cfg)
        sp["cm"] = rwkv6.rwkv_channel_specs(cfg)
    else:
        raise ValueError(kind)
    return sp


# --------------------------------------------------------------------------- #
# Attention apply
# --------------------------------------------------------------------------- #
def _ring_positions(pos: jax.Array, window: int) -> jax.Array:
    """Absolute position stored in each ring-buffer slot, given cursor pos."""
    idx = jnp.arange(window)
    return pos - ((pos - idx) % window)


def attn_apply(params: dict, x: jax.Array, cfg: ModelConfig, ctx: RunContext,
               rope: Tuple[jax.Array, jax.Array], cache: Optional[dict],
               mode: str, prefix_len: int, pos,
               cache_capacity: int = 0, block_tables=None,
               block_size: int = 0) -> Tuple[jax.Array, Optional[dict]]:
    cos, sin = rope
    q = jnp.einsum("bsd,dhn->bshn", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhn->bshn", x, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhn->bshn", x, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    # --- attention sharding mode (DESIGN.md §4) ---
    # heads-TP when n_heads divides the model axis; otherwise sequence-
    # parallel Q (tiny GQA K/V replicated over model) so the score tensor
    # is always sharded over the model axis.
    seq_mode = False
    seq_shards = 1
    constrain_cb = None
    if ctx.mesh is not None and mode not in ("decode", "chunk"):
        m = ctx.model_axis
        msz = ctx.model_size
        from repro.models.model import constrain
        use_seq = (ctx.zero_sp or cfg.n_heads % msz != 0) \
            and x.shape[1] % msz == 0
        if use_seq:
            seq_mode = True
            seq_shards = msz
            q = constrain(q, ctx, m, None, None)
            k = constrain(k, ctx, None, None, None)
            v = constrain(v, ctx, None, None, None)

            def constrain_cb(t):
                # pin the sharded q-row block dim (dim 1) to the model axis
                return constrain(t, ctx, m, *([None] * (t.ndim - 2)))
        elif cfg.n_heads % msz == 0:
            q = constrain(q, ctx, None, m, None)
            kv_m = m if cfg.n_kv_heads % msz == 0 else None
            k = constrain(k, ctx, None, kv_m, None)
            v = constrain(v, ctx, None, kv_m, None)

    new_cache = None
    if mode == "decode" and block_tables is not None:
        # Paged decode: the cache leaves are a physical block pool
        # (num_blocks, block_size, Hkv, D) shared by every slot; each row of
        # the batch is one slot with its own cursor ``pos[i]`` and its own
        # row of ``block_tables``.  The new token's K/V lands in the slot's
        # current block; attention gathers the slot's blocks in logical
        # order.  Inactive slots point every table entry at block 0 (the
        # reserved trash block), so their writes can never corrupt live KV.
        if cfg.window is not None:
            raise NotImplementedError("paged KV cache requires full "
                                      "attention (cfg.window=None)")
        bsz = x.shape[0]
        rows = jnp.arange(bsz)
        blk = block_tables[rows, pos // block_size]          # (B,)
        off = pos % block_size
        ck = cache["k"].at[blk, off].set(k[:, 0])
        cv = cache["v"].at[blk, off].set(v[:, 0])
        if ctx.impl == "pallas":
            from repro.kernels import ops as kops
            out = kops.paged_attention(q, ck, cv, block_tables, pos + 1,
                                       softcap=cfg.logit_softcap,
                                       fused=ctx.paged_fused)
        else:
            hkv_n = ck.shape[2]
            kg = ck[block_tables].reshape(bsz, -1, hkv_n, ck.shape[3])
            vg = cv[block_tables].reshape(bsz, -1, hkv_n, cv.shape[3])
            out = attention_xla(q, kg, vg, causal=True, window=None,
                                softcap=cfg.logit_softcap, q_offset=pos)
        new_cache = {"k": ck, "v": cv}
    elif mode == "chunk":
        # Chunked paged prefill (ADR-005): each batch row carries a C-token
        # chunk of its uncached suffix.  ``pos`` is (pos0, n_live) — the
        # chunk's starting cursor and its live token count (0..C; 0 = dead
        # row).  The chunk's K/V is scattered into the slot's paged blocks
        # through the block table, then attention runs over all previously
        # resident blocks plus the chunk itself (causal).  Writes mirror the
        # stepwise scan exactly: dead tokens write block 0 (trash), tokens
        # clamped at capacity-1 collapse to one write holding the *last*
        # live token's K/V (last-live-wins = the stepwise final state).
        if cfg.window is not None:
            raise NotImplementedError("chunked prefill requires full "
                                      "attention (cfg.window=None)")
        pos0, n_live = pos
        bsz, csz = x.shape[0], x.shape[1]
        cap = cache_capacity
        cidx = jnp.arange(csz)
        cpos = pos0[:, None] + cidx[None, :]                 # (B, C)
        live = cidx[None, :] < n_live[:, None]
        wpos = jnp.minimum(cpos, cap - 1)
        writer = live & ((cpos < cap - 1)
                         | (cidx[None, :] == n_live[:, None] - 1))
        blk_col = jnp.minimum(wpos // block_size,
                              block_tables.shape[1] - 1)
        blk = jnp.where(writer,
                        jnp.take_along_axis(block_tables, blk_col, axis=1), 0)
        off = jnp.where(writer, wpos % block_size, 0)
        ck = cache["k"].at[blk, off].set(k)
        cv = cache["v"].at[blk, off].set(v)
        if ctx.impl == "pallas":
            from repro.kernels import ops as kops
            out = kops.paged_prefill(q, ck, cv, block_tables, pos0, n_live,
                                     softcap=cfg.logit_softcap)
        else:
            hkv_n = ck.shape[2]
            kg = ck[block_tables].reshape(bsz, -1, hkv_n, ck.shape[3])
            vg = cv[block_tables].reshape(bsz, -1, hkv_n, cv.shape[3])
            out = attention_xla(q, kg, vg, causal=True, window=None,
                                softcap=cfg.logit_softcap, q_offset=pos0)
        new_cache = {"k": ck, "v": cv}
    elif mode == "decode":
        capacity = cache["k"].shape[1]
        if cfg.window is not None and capacity == cfg.window:
            slot = pos % capacity
            k_pos = _ring_positions(pos, capacity)
        else:
            slot = jnp.minimum(pos, capacity - 1)
            k_pos = jnp.arange(capacity)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        k_pos = jnp.broadcast_to(k_pos, (x.shape[0], capacity))
        out = attention_xla(q, ck, cv, causal=True, window=cfg.window,
                            softcap=cfg.logit_softcap, q_offset=pos,
                            k_pos=k_pos)
        new_cache = {"k": ck, "v": cv}
    else:
        if ctx.impl == "pallas" and cfg.causal and prefix_len == 0:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True,
                                       window=cfg.window,
                                       softcap=cfg.logit_softcap)
        else:
            out = attention_xla(q, k, v, causal=cfg.causal, window=cfg.window,
                                prefix_len=prefix_len,
                                softcap=cfg.logit_softcap,
                                seq_shards=seq_shards,
                                constrain_cb=constrain_cb,
                                unroll_chunks=ctx.scan_unroll)
        if mode == "prefill":
            w = cfg.window
            s = x.shape[1]
            if w is not None and s >= w:
                # ring cache; prefill length is a multiple of the window in
                # all assigned shapes, so slots line up with positions mod w
                new_cache = {"k": k[:, -w:], "v": v[:, -w:]}
            else:
                cap = cache_capacity or s
                pad = [(0, 0), (0, cap - s), (0, 0), (0, 0)]
                new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
            if ctx.mesh is not None:
                # pin the produced cache to its storage sharding (kv_heads
                # or seq over model) so the stacked scan output is never
                # materialized replicated
                from repro.models.model import constrain
                m2 = ctx.model_axis
                kv_m = m2 if (cfg.n_kv_heads % ctx.model_size == 0
                              and not ctx.zero_sp) else None
                seq_m = None if kv_m else m2
                new_cache = {
                    kk: constrain(vv, ctx, seq_m, kv_m, None)
                    for kk, vv in new_cache.items()}
    if seq_mode and not ctx.zero_sp:
        from repro.models.model import constrain
        out = constrain(out, ctx, None, None, None)   # gather seq shards
    elif seq_mode:
        from repro.models.model import constrain
        out = constrain(out, ctx, ctx.model_axis, None, None)  # stay sharded
    out = jnp.einsum("bshn,hnd->bsd", out, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, new_cache


# --------------------------------------------------------------------------- #
# Generic residual block
# --------------------------------------------------------------------------- #
def block_apply(kind: str, params: dict, x: jax.Array, cfg: ModelConfig,
                ctx: RunContext, rope, cache: Optional[dict], mode: str,
                prefix_len: int, pos, cache_capacity: int = 0,
                block_tables=None, block_size: int = 0):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(x, params["norm1"], cfg.norm_type)
    if kind == "attn":
        mix, mix_cache = attn_apply(params["attn"], h, cfg, ctx, rope,
                                    cache, mode, prefix_len, pos,
                                    cache_capacity, block_tables, block_size)
    elif kind == "rglru":
        mix, mix_cache = rglru.rglru_block_apply(params["rec"], h, cfg, ctx,
                                                 cache, mode)
    elif kind == "rwkv":
        tm_cache = cache["tm"] if cache is not None else None
        mix, mix_cache = rwkv6.rwkv_time_apply(params["tm"], h, cfg, ctx,
                                               tm_cache, mode)
    else:
        raise ValueError(kind)
    x = x + mix

    h2 = apply_norm(x, params["norm2"], cfg.norm_type)
    ffn_cache = None
    if kind == "rwkv":
        cm_cache = cache["cm"] if cache is not None else None
        ffn, ffn_cache = rwkv6.rwkv_channel_apply(params["cm"], h2, cfg,
                                                  cm_cache, mode)
    elif kind == "attn" and cfg.is_moe:
        ffn, aux = moe_apply(params["ffn"], h2, cfg, ctx)
    else:
        ffn = mlp(params["ffn"], h2, cfg.mlp_act, cfg.mlp_gated)
    x = x + ffn

    if kind == "rwkv":
        new_cache = ({"tm": mix_cache, "cm": ffn_cache}
                     if mix_cache is not None else None)
    else:
        new_cache = mix_cache
    return x, new_cache, aux


def block_cache_axes(cfg: ModelConfig, kind: str):
    """Logical sharding axes mirroring ``init_block_cache`` structure.

    kv_heads takes the model axis when divisible; otherwise the cache
    sequence dim does ("kv_seq" is lower priority than "kv_heads" in
    distributed.sharding._PRIORITY, so exactly one of them claims it).
    """
    if kind == "attn":
        kv = ("batch", "kv_seq", "kv_heads", "head_dim")
        return {"k": kv, "v": kv}
    if kind == "rglru":
        return {"h": ("batch", "lru"), "conv": ("batch", None, "lru")}
    if kind == "rwkv":
        return {"tm": {"prev": ("batch", None),
                       "s": ("batch", "heads", None, None)},
                "cm": {"prev": ("batch", None)}}
    raise ValueError(kind)


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, capacity: int,
                     dtype):
    """Zero cache for one block."""
    if kind == "attn":
        cap = min(capacity, cfg.window) if cfg.window else capacity
        shape = (batch, cap, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "rglru":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    if kind == "rwkv":
        return rwkv6.init_rwkv_cache(cfg, batch, dtype)
    raise ValueError(kind)
