"""RG-LRU recurrent block (Griffin / recurrentgemma).

Reference path: temporal depthwise conv + gated linear recurrence evaluated
with ``jax.lax.associative_scan`` (log-depth, XLA-native).  The TPU hot path
is the chunked Pallas kernel in ``repro.kernels.rglru_scan`` validated against
this implementation.

Block structure (Griffin, arXiv:2402.19427):
    y = W_out[ RG-LRU(conv1d(x W_x)) * gelu(x W_y) ]
    r_t = sigmoid(x_t W_a);  i_t = sigmoid(x_t W_i)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.context import RunContext
from repro.models.spec import ParamSpec

_C = 8.0


def rglru_specs(cfg: ModelConfig):
    d = cfg.d_model
    r = cfg.d_model                     # lru_width = d_model
    w = cfg.conv1d_width
    return {
        "wx": ParamSpec((d, r), ("embed", "lru")),
        "wy": ParamSpec((d, r), ("embed", "lru")),
        "conv_w": ParamSpec((w, r), (None, "lru"), fan_in=w),
        "conv_b": ParamSpec((r,), ("lru",), init="zeros"),
        "wa": ParamSpec((r, r), ("lru_in", "lru")),
        "ba": ParamSpec((r,), ("lru",), init="zeros"),
        "wi": ParamSpec((r, r), ("lru_in", "lru")),
        "bi": ParamSpec((r,), ("lru",), init="zeros"),
        "lam": ParamSpec((r,), ("lru",), init="ones"),
        "wout": ParamSpec((r, d), ("lru", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 carry: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B,S,R); w: (W,R); carry: (B,W-1,R)."""
    width = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width)) + b
    return y.astype(x.dtype), xp[:, -(width - 1):]


def _gates(xc: jax.Array, p: dict):
    """Returns (a, mult*i*xc) in fp32 — the linear-recurrence coefficients."""
    xf = xc.astype(jnp.float32)
    rg = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32)
                        + p["ba"].astype(jnp.float32))
    ig = jax.nn.sigmoid(xf @ p["wi"].astype(jnp.float32)
                        + p["bi"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * rg
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, mult * ig * xf


def rglru_scan_ref(xc: jax.Array, p: dict,
                   h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence recurrence via associative scan. xc: (B,S,R)."""
    a, b = _gates(xc, p)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return ar * al, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(xc.dtype), h[:, -1].astype(jnp.float32)


def rglru_step(xc: jax.Array, p: dict, h0: jax.Array):
    """Single decode step. xc: (B,1,R); h0: (B,R) fp32."""
    a, b = _gates(xc, p)
    h = a[:, 0] * h0 + b[:, 0]
    return h[:, None].astype(xc.dtype), h


def rglru_block_apply(params: dict, x: jax.Array, cfg: ModelConfig,
                      ctx: RunContext, cache: Optional[dict], mode: str):
    """x: (B,S,D) -> (y, new_cache). cache = {"h": (B,R) f32, "conv": (B,W-1,R)}."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dr->bsr", x, params["wy"],
                   preferred_element_type=jnp.float32)).astype(x.dtype)
    xb = jnp.einsum("bsd,dr->bsr", x, params["wx"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    conv_carry = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xb, params["conv_w"], params["conv_b"],
                                conv_carry)
    if mode == "decode":
        h_seq, h_last = rglru_step(xc, params, cache["h"])
    elif ctx.impl == "pallas":
        from repro.kernels import ops as kops
        h0 = cache["h"] if cache is not None else None
        a, b = _gates(xc, params)
        h_seq, h_last = kops.rglru_scan(a, b, h0=h0)
        h_seq = h_seq.astype(xc.dtype)
    else:
        h0 = cache["h"] if cache is not None else None
        h_seq, h_last = rglru_scan_ref(xc, params, h0)
    y = jnp.einsum("bsr,rd->bsd", h_seq * gate, params["wout"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = None
    if cache is not None or mode == "prefill":
        new_cache = {"h": h_last, "conv": new_conv}
    return y, new_cache


def _gates_tuple(xc, p):
    a, b = _gates(xc, p)
    return a, b


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    r, w = cfg.d_model, cfg.conv1d_width
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, w - 1, r), dtype)}
