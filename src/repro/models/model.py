"""Unified model: specs, init, forward (train/prefill), decode step, loss.

Layer stacks are ``lax.scan``-over-groups with stacked params: HLO size and
compile time are independent of depth (essential for 512-device dry-runs).
A "group" is one repetition of ``cfg.block_pattern``; layers left over after
the last full group ("rest") are applied unscanned.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.context import RunContext
from repro.models.layers import apply_norm, rope_tables
from repro.models.spec import (ParamSpec, abstract_params, init_params,
                               logical_axes, param_count, stack_specs)

_AUX_COEF = 0.01


def constrain(x: jax.Array, ctx: RunContext, *trailing) -> jax.Array:
    """with_sharding_constraint helper: batch dim over dp axes + trailing
    logical entries given as mesh-axis names (or None).  GSPMD's propagation
    through scan loops is weak; these pins keep activations batch-sharded.
    """
    if ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = ctx.dp_spec()
    dp_entry = dp if x.shape[0] % ctx.dp_size == 0 else None
    entries = [dp_entry]
    for size, name in zip(x.shape[1:], trailing):
        if name is not None and size % ctx.mesh.shape[name] == 0:
            entries.append(name)
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*entries)))


# --------------------------------------------------------------------------- #
# Grouping
# --------------------------------------------------------------------------- #
def grouping(cfg: ModelConfig):
    """(pattern, n_groups, rest_kinds)."""
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    rest = cfg.layer_kinds()[n_groups * len(pat):]
    return pat, n_groups, rest


# --------------------------------------------------------------------------- #
# Specs / init
# --------------------------------------------------------------------------- #
def param_specs(cfg: ModelConfig) -> Dict:
    d, v = cfg.d_model, cfg.vocab_size
    pat, n_groups, rest = grouping(cfg)
    sp: Dict = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), fan_in=d),
        "final_norm": blocks.norm_specs(cfg),
        "layers": {
            "stack": {f"b{i}": stack_specs(blocks.block_specs(cfg, kind),
                                           n_groups)
                      for i, kind in enumerate(pat)},
            "rest": {f"r{i}": blocks.block_specs(cfg, kind)
                     for i, kind in enumerate(rest)},
        },
    }
    if not cfg.tie_embeddings:
        sp["head"] = ParamSpec((d, v), ("embed", "vocab"))
    if cfg.frontend is not None:
        sp["frontend"] = {"proj": ParamSpec((d, d), ("embed", "embed_out"))}
    # honor cfg.dtype: bf16-default specs follow the config (explicit fp32
    # specs — norms stats, decay params — stay fp32)
    if cfg.dtype != "bfloat16":
        target = jnp.dtype(cfg.dtype)
        sp = jax.tree.map(
            lambda s: dataclasses.replace(s, dtype=target)
            if s.dtype == jnp.bfloat16 else s,
            sp, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sp


def init(cfg: ModelConfig, key: jax.Array):
    return init_params(param_specs(cfg), key)


def init_abstract(cfg: ModelConfig):
    return abstract_params(param_specs(cfg))


def param_logical_axes(cfg: ModelConfig):
    return logical_axes(param_specs(cfg))


def n_params(cfg: ModelConfig) -> int:
    return param_count(param_specs(cfg))


def n_active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of n_experts expert params)."""
    if not cfg.is_moe:
        return n_params(cfg)
    total = n_params(cfg)
    specs = param_specs(cfg)
    expert_leaves = [
        s for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        if len(s.shape) >= 3 and cfg.n_experts in s.shape[:2] and s.shape[-1] != cfg.n_experts
    ]
    expert_total = sum(int(np.prod(s.shape)) for s in expert_leaves)
    return total - expert_total + expert_total * cfg.top_k // cfg.n_experts


# --------------------------------------------------------------------------- #
# Cache
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=None) -> Dict:
    """Zero decode cache, stacked to match the scan grouping."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    pat, n_groups, rest = grouping(cfg)

    def stacked(kind):
        one = blocks.init_block_cache(cfg, kind, batch, capacity, dtype)
        return jax.tree.map(
            lambda a: jnp.zeros((n_groups,) + a.shape, a.dtype), one)

    return {
        "stack": {f"b{i}": stacked(kind) for i, kind in enumerate(pat)},
        "rest": {f"r{i}": blocks.init_block_cache(cfg, kind, batch, capacity,
                                                  dtype)
                 for i, kind in enumerate(rest)},
    }


def abstract_cache(cfg: ModelConfig, batch: int, capacity: int):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, capacity))


def cache_axes(cfg: ModelConfig):
    """Locate each cache leaf's (batch_axis, capacity_axis) by shape diffing.

    Returns two trees matching :func:`init_cache`'s structure: the axis that
    scales with batch, and the axis that scales with capacity (``None`` for
    per-row state leaves such as recurrent hidden states, which have no
    sequence storage).  The paged-KV machinery uses these to treat KV leaves
    as block pools and state leaves as slot-indexed rows, without
    hard-coding any block's cache layout.
    """
    def diff_axis(x, y):
        d = [i for i, (p, q) in enumerate(zip(x.shape, y.shape)) if p != q]
        return d[0] if d else None

    b1, b2 = abstract_cache(cfg, 1, 16), abstract_cache(cfg, 2, 16)
    c1, c2 = abstract_cache(cfg, 1, 8), abstract_cache(cfg, 1, 16)
    return (jax.tree.map(diff_axis, b1, b2), jax.tree.map(diff_axis, c1, c2))


def init_paged_cache(cfg: ModelConfig, max_slots: int, num_blocks: int,
                     block_size: int, dtype=None) -> Dict:
    """Zero paged decode cache: a block pool plus per-slot state rows.

    Attention KV leaves become physical block pools — the contiguous
    (B, capacity, Hkv, D) storage is replaced by (num_blocks, block_size,
    Hkv, D); which blocks belong to which slot is the caller's block table.
    Leaves with no capacity axis (recurrent state) keep one row per slot:
    (max_slots, ...).  Block id 0 is conventionally the trash block that
    inactive slots write into; allocators should never hand it out.
    """
    if cfg.window is not None:
        raise NotImplementedError("paged KV cache requires full attention "
                                  "(cfg.window=None)")
    pool = init_cache(cfg, num_blocks, block_size, dtype)
    state = init_cache(cfg, max_slots, 1, dtype)
    _, cap_ax = cache_axes(cfg)
    return jax.tree.map(
        lambda kv, st, ax: kv if ax is not None else st, pool, state, cap_ax)


def cache_logical_axes(cfg: ModelConfig):
    """Logical axes tree matching ``init_cache`` (leading layer-stack dim)."""
    pat, _, rest = grouping(cfg)

    def stacked(kind):
        one = blocks.block_cache_axes(cfg, kind)
        return jax.tree.map(lambda a: ("layers",) + a, one,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and all(isinstance(e, (str, type(None)))
                                    for e in x))

    return {
        "stack": {f"b{i}": stacked(kind) for i, kind in enumerate(pat)},
        "rest": {f"r{i}": blocks.block_cache_axes(cfg, kind)
                 for i, kind in enumerate(rest)},
    }


# --------------------------------------------------------------------------- #
# Input embedding (text / audio-stub / vision-stub frontends)
# --------------------------------------------------------------------------- #
def embed_inputs(cfg: ModelConfig, params: Dict, batch: Dict):
    """Returns (x, positions, prefix_len)."""
    dtype = jnp.dtype(cfg.dtype)
    emb = params["embed"]

    def tok_embed(tokens):
        x = jnp.take(emb, tokens, axis=0).astype(dtype)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
        return x

    if cfg.frontend == "audio":
        x = jnp.einsum("bsd,de->bse", batch["frames"].astype(dtype),
                       params["frontend"]["proj"]).astype(dtype)
        prefix_len = 0
    elif cfg.frontend == "vision":
        patches = jnp.einsum("bpd,de->bpe", batch["patches"].astype(dtype),
                             params["frontend"]["proj"]).astype(dtype)
        x = jnp.concatenate([patches, tok_embed(batch["tokens"])], axis=1)
        prefix_len = patches.shape[1]
    else:
        x = tok_embed(batch["tokens"])
        prefix_len = 0
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions, prefix_len


def unembed(cfg: ModelConfig, params: Dict, x: jax.Array,
            ctx: RunContext) -> jax.Array:
    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"],
                            preferred_element_type=jnp.float32)
    logits = constrain(logits, ctx, None, ctx.model_axis)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


# --------------------------------------------------------------------------- #
# Stack application
# --------------------------------------------------------------------------- #
def _remat_wrap(fn, ctx: RunContext, mode: str):
    if mode != "train" or ctx.remat == "none":
        return fn
    if ctx.remat == "dots":
        policy = jax.checkpoint_policies.dots_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def apply_stack(cfg: ModelConfig, params: Dict, x: jax.Array,
                ctx: RunContext, rope, cache: Optional[Dict], mode: str,
                prefix_len: int, pos, cache_capacity: int = 0,
                block_tables=None, block_size: int = 0):
    """Runs all layers. Returns (x, new_cache, aux)."""
    pat, n_groups, rest = grouping(cfg)
    want_cache = cache is not None or mode == "prefill"

    seq_ax = ctx.model_axis if (ctx.mesh is not None and ctx.zero_sp) else None

    def group_body(carry, xs):
        xc, aux = carry
        xc = constrain(xc, ctx, seq_ax, None)
        layer_params, layer_cache = xs
        new_caches = {}
        for i, kind in enumerate(pat):
            c_i = None if layer_cache is None else layer_cache[f"b{i}"]
            xc, nc, a = blocks.block_apply(kind, layer_params[f"b{i}"], xc,
                                           cfg, ctx, rope, c_i, mode,
                                           prefix_len, pos, cache_capacity,
                                           block_tables, block_size)
            if want_cache:
                new_caches[f"b{i}"] = nc
        return (xc, aux + a), (new_caches if want_cache else None)

    body = _remat_wrap(group_body, ctx, mode)
    aux0 = jnp.zeros((), jnp.float32)
    cache_stack = None if cache is None else cache["stack"]
    if n_groups > 0:
        (x, aux), new_stack = jax.lax.scan(
            body, (x, aux0), (params["layers"]["stack"], cache_stack),
            unroll=n_groups if ctx.scan_unroll else 1)
    else:
        aux, new_stack = aux0, None

    new_rest = {}
    for i, kind in enumerate(rest):
        c_i = None if cache is None else cache["rest"][f"r{i}"]
        x, nc, a = blocks.block_apply(kind, params["layers"]["rest"][f"r{i}"],
                                      x, cfg, ctx, rope, c_i, mode,
                                      prefix_len, pos, cache_capacity,
                                      block_tables, block_size)
        aux = aux + a
        if want_cache:
            new_rest[f"r{i}"] = nc
    new_cache = {"stack": new_stack, "rest": new_rest} if want_cache else None
    return x, new_cache, aux


# --------------------------------------------------------------------------- #
# Losses
# --------------------------------------------------------------------------- #
def _ce_vocab_sharded(logits: jax.Array, targets: jax.Array,
                      ctx: RunContext) -> jax.Array:
    """Per-token CE with the vocab dim sharded over the model axis.

    A plain take_along_axis over a sharded vocab makes GSPMD all-gather the
    full logits (e.g. 13 GiB/dev for smollm train_4k); inside shard_map each
    shard reduces its local vocab slice and three scalar-ish psums combine.
    """
    from jax.sharding import PartitionSpec as P
    m = ctx.model_axis
    b = logits.shape[0]
    dp = ctx.dp_spec() if b % ctx.dp_size == 0 else None

    def body(lg, tg):
        lg = lg.astype(jnp.float32)
        v_loc = lg.shape[-1]
        off = jax.lax.axis_index(m) * v_loc
        # stop_gradient: lse is shift-invariant, so treating the max as a
        # constant yields exact gradients (and pmax has no JVP rule —
        # the stop must sit *inside* so pmax never sees a tangent)
        lmax = jax.lax.pmax(
            jax.lax.stop_gradient(jnp.max(lg, axis=-1)), m)
        z = jnp.exp(lg - lmax[..., None])
        denom = jax.lax.psum(jnp.sum(z, axis=-1), m)
        idx = tg - off
        ok = (idx >= 0) & (idx < v_loc)
        safe = jnp.clip(idx, 0, v_loc - 1)
        ll_loc = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
        ll = jax.lax.psum(jnp.where(ok, ll_loc, 0.0), m)
        return jnp.log(denom) + lmax - ll

    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(
        body, mesh=ctx.mesh,
        in_specs=(P(dp, None, m), P(dp, None)),
        out_specs=P(dp, None))(logits, targets)


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array], chunk: int = 0,
                  ctx: Optional[RunContext] = None):
    """Stable CE over (possibly vocab-sharded) logits. logits: (B,S,V) f32."""
    if (ctx is not None and ctx.mesh is not None
            and logits.shape[-1] % ctx.model_size == 0):
        losses = _ce_vocab_sharded(logits, targets, ctx)
        if mask is None:
            return jnp.mean(losses)
        mask = mask.astype(jnp.float32)
        return jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    def ce(lg, tg):
        lg = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
        return lse - ll

    if chunk and logits.shape[1] % chunk == 0 and logits.shape[1] > chunk:
        b, s, v = logits.shape
        n = s // chunk
        lg = logits.reshape(b, n, chunk, v).swapaxes(0, 1)
        tg = targets.reshape(b, n, chunk).swapaxes(0, 1)
        losses = jax.lax.map(lambda args: ce(*args), (lg, tg))
        losses = losses.swapaxes(0, 1).reshape(b, s)
    else:
        losses = ce(logits, targets)
    if mask is None:
        return jnp.mean(losses)
    mask = mask.astype(jnp.float32)
    return jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------- #
# Public entry points
# --------------------------------------------------------------------------- #
def forward(cfg: ModelConfig, params: Dict, batch: Dict, ctx: RunContext,
            mode: str = "train", cache_capacity: int = 0):
    """mode="train" -> (loss, metrics); mode="prefill" -> (last_logits, cache)."""
    x, positions, prefix_len = embed_inputs(cfg, params, batch)
    seq_ax = ctx.model_axis if (ctx.mesh is not None and ctx.zero_sp) else None
    x = constrain(x, ctx, seq_ax, None)
    rope = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    x, new_cache, aux = apply_stack(cfg, params, x, ctx, rope, None, mode,
                                    prefix_len, pos=None,
                                    cache_capacity=cache_capacity)
    if mode == "prefill":
        logits = unembed(cfg, params, x[:, -1:], ctx)
        return logits[:, 0], new_cache
    logits = unembed(cfg, params, x, ctx)
    if cfg.frontend == "vision":
        # loss over the text suffix only
        logits = logits[:, prefix_len:]
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    loss = cross_entropy(logits, targets, mask, ctx.loss_chunk, ctx)
    total = loss + _AUX_COEF * aux
    return total, {"loss": loss, "aux": aux}


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, pos: jax.Array, ctx: RunContext,
                block_tables: Optional[jax.Array] = None,
                block_size: int = 0):
    """One decode step. tokens: (B,1) int32.

    ``pos`` is the decode cursor: a scalar int32 when the whole batch shares
    one position (contiguous cohort cache), or a (B,) int32 vector of
    *per-slot* cursors when ``block_tables`` (B, M) maps each row onto a
    paged KV block pool (leaves (num_blocks, block_size, Hkv, D) instead of
    (B, capacity, Hkv, D)).  Per-slot cursors are what let a late arrival
    join an in-flight batch: rows no longer share a position.

    Returns (logits (B,V), new_cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    b = x.shape[0]
    if jnp.ndim(pos) == 0:
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
    else:
        positions = pos[:, None]
    rope = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    x, new_cache, _ = apply_stack(cfg, params, x, ctx, rope, cache, "decode",
                                  prefix_len=0, pos=pos,
                                  block_tables=block_tables,
                                  block_size=block_size)
    logits = unembed(cfg, params, x, ctx)
    return logits[:, 0], new_cache


def decode_loop(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, pos: jax.Array, steps_left: jax.Array,
                ctx: RunContext, *, block_tables: jax.Array,
                block_size: int, num_steps: int, capacity: int):
    """Fused multi-token decode: ``num_steps`` greedy steps in ONE dispatch.

    A ``lax.scan`` over T :func:`decode_step` calls, entirely on device —
    greedy (argmax) sampling, per-slot cursor advance, and the block-table-
    indexed KV writes all happen inside the scan, so the host↔device
    round-trip cost drops from one-per-token to one-per-window.

    tokens: (B, 1) int32 — each slot's current token; pos: (B,) int32
    per-slot cursors (``kv.pos`` convention: may equal ``capacity``);
    steps_left: (B,) int32 — tokens still to emit per slot this window.
    A row whose ``steps_left`` is exhausted (or 0: an empty slot) is *dead*:
    its table row and cursor are masked to 0 so its KV write lands in the
    trash block (block 0) and its emitted token freezes — the host frees
    the slot's real blocks only at the window boundary, so mid-window
    completions can never corrupt live KV.  Cursor advance clamps exactly
    like the per-token serving path (write position pins to ``capacity-1``
    past the end), which is what makes the window token-identical to T
    calls of :func:`decode_step`.

    Returns (tokens_out (B, T) int32, new_cache); row i of ``tokens_out``
    holds the token emitted at each step (frozen once the row dies).
    """
    tok0 = tokens[:, 0].astype(jnp.int32)
    tables = block_tables.astype(jnp.int32)
    steps_left = steps_left.astype(jnp.int32)

    def step(carry, t):
        cache, tok, cur = carry
        live = t < steps_left
        eff_tables = jnp.where(live[:, None], tables, 0)
        eff_pos = jnp.where(live, jnp.minimum(cur, capacity - 1), 0)
        logits, cache = decode_step(cfg, params, cache, tok[:, None],
                                    eff_pos, ctx, block_tables=eff_tables,
                                    block_size=block_size)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        nxt = jnp.where(live, nxt, tok)
        cur = jnp.where(live, jnp.minimum(cur + 1, capacity), cur)
        return (cache, nxt, cur), nxt

    (cache, _, _), toks = jax.lax.scan(
        step, (cache, tok0, pos.astype(jnp.int32)),
        jnp.arange(num_steps, dtype=jnp.int32))
    return jnp.swapaxes(toks, 0, 1), cache


def prefill_loop(cfg: ModelConfig, params: Dict, cache: Dict,
                 tokens: jax.Array, pos0: jax.Array, n_tokens: jax.Array,
                 ctx: RunContext, *, block_tables: jax.Array,
                 block_size: int, num_steps: int, capacity: int):
    """Suffix prefill over a paged pool: teacher-forced decode scan.

    The restore / prefix-hit path of the copy-on-write prefix cache
    (docs/architecture.md ADR-003): a row whose prompt prefix is already
    resident in cached KV blocks only needs its *uncached suffix* written —
    starting from a per-row offset ``pos0[i]``, which a batched
    ``forward(mode="prefill")`` cannot do (it always starts at position 0).
    This runs the suffix through :func:`decode_step` under one ``lax.scan``
    dispatch: step ``t`` feeds the given token ``tokens[i, t]`` (teacher
    forcing — no sampling), writes its KV at position ``pos0[i] + t``
    through the row's block table, and attends over the full context so
    far — cached prefix blocks included.

    tokens: (B, T) int32 suffix tokens, rows padded past ``n_tokens[i]``;
    pos0: (B,) int32 first uncached position per row (the cached-prefix
    length); n_tokens: (B,) int32 live suffix length per row (0 = inactive
    pad row: its writes park in the trash block, like ``decode_loop``).

    Returns (first_tokens (B,), new_cache): ``first_tokens[i]`` is the
    greedy next token after row i's last suffix position — the row's first
    generated token, exactly what a full prefill's final logits yield.
    """
    tables = block_tables.astype(jnp.int32)
    n_tokens = n_tokens.astype(jnp.int32)
    pos0 = pos0.astype(jnp.int32)
    first0 = jnp.zeros((tokens.shape[0],), jnp.int32)

    def step(carry, xs):
        cache, first = carry
        t, tok_t = xs
        live = t < n_tokens
        eff_tables = jnp.where(live[:, None], tables, 0)
        eff_pos = jnp.where(live, jnp.minimum(pos0 + t, capacity - 1), 0)
        logits, cache = decode_step(cfg, params, cache, tok_t[:, None],
                                    eff_pos, ctx, block_tables=eff_tables,
                                    block_size=block_size)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        first = jnp.where(t == n_tokens - 1, nxt, first)
        return (cache, first), None

    xs = (jnp.arange(num_steps, dtype=jnp.int32),
          jnp.swapaxes(tokens.astype(jnp.int32), 0, 1))
    (cache, first), _ = jax.lax.scan(step, (cache, first0), xs)
    return first, cache


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Whether :func:`prefill_chunks` covers this architecture.

    Chunked prefill needs every layer to be full (windowless) attention:
    recurrent kinds (rglru/rwkv) carry sequential per-token state a chunk
    cannot parallelize, and sliding windows are rejected by the paged pool
    anyway.  Callers fall back to the stepwise :func:`prefill_loop` scan.
    """
    return cfg.window is None and \
        all(k == "attn" for k in cfg.layer_kinds())


def chunk_step(cfg: ModelConfig, params: Dict, cache: Dict,
               tokens: jax.Array, pos0: jax.Array, n_live: jax.Array,
               ctx: RunContext, *, block_tables: jax.Array,
               block_size: int, capacity: int):
    """One C-token chunk of suffix prefill per row, in ONE model pass.

    The chunked sibling of :func:`decode_step`: tokens (B, C) int32 are a
    chunk of each row's uncached suffix starting at cursor ``pos0[i]``, of
    which the first ``n_live[i]`` (0..C) are real.  Every layer scatters
    the chunk's K/V into the row's paged blocks through ``block_tables``
    and attends over the resident prefix plus the chunk (causal) — see the
    ``mode="chunk"`` branch of ``blocks.attn_apply``.

    Returns (last_logits (B, V), new_cache): the logits after each row's
    *last live* token (rows with ``n_live == 0`` yield garbage the caller
    masks out).  Position embedding clamps to ``capacity - 1`` exactly like
    the stepwise scan, so live-token computation is bitwise identical.
    """
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    c = x.shape[1]
    pos0 = pos0.astype(jnp.int32)
    n_live = n_live.astype(jnp.int32)
    positions = jnp.minimum(pos0[:, None] + jnp.arange(c), capacity - 1)
    rope = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    x, new_cache, _ = apply_stack(cfg, params, x, ctx, rope, cache, "chunk",
                                  prefix_len=0, pos=(pos0, n_live),
                                  cache_capacity=capacity,
                                  block_tables=block_tables,
                                  block_size=block_size)
    idx = jnp.clip(n_live - 1, 0, c - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)   # (B, 1, D)
    logits = unembed(cfg, params, x_last, ctx)
    return logits[:, 0], new_cache


def prefill_chunks(cfg: ModelConfig, params: Dict, cache: Dict,
                   tokens: jax.Array, pos0: jax.Array, n_tokens: jax.Array,
                   ctx: RunContext, *, block_tables: jax.Array,
                   block_size: int, chunk: int, num_steps: int,
                   capacity: int):
    """Chunked suffix prefill: :func:`prefill_loop` at C tokens per step.

    Same contract as :func:`prefill_loop` — tokens (B, T) suffix rows,
    per-row start cursors ``pos0`` and live lengths ``n_tokens`` — but the
    scan advances ``chunk`` tokens per step via :func:`chunk_step`, so a
    T-token suffix costs ⌈T/chunk⌉ sequential steps instead of T.  Token-
    identical to the stepwise scan (greedy first token per row), including
    the trash-block parking of dead rows and the ``capacity - 1`` clamp.

    ``num_steps`` is the number of *chunk* steps (⌈T_pad/chunk⌉); tokens
    are padded on device to ``num_steps * chunk`` columns.
    """
    tables = block_tables.astype(jnp.int32)
    n_tokens = n_tokens.astype(jnp.int32)
    pos0 = pos0.astype(jnp.int32)
    toks = tokens.astype(jnp.int32)
    pad = num_steps * chunk - toks.shape[1]
    if pad > 0:
        toks = jnp.pad(toks, ((0, 0), (0, pad)))
    first0 = jnp.zeros((toks.shape[0],), jnp.int32)

    def step(carry, t):
        cache, first = carry
        base = t * chunk
        tok_c = jax.lax.dynamic_slice_in_dim(toks, base, chunk, axis=1)
        n_live = jnp.clip(n_tokens - base, 0, chunk)
        eff_tables = jnp.where((n_live > 0)[:, None], tables, 0)
        logits, cache = chunk_step(cfg, params, cache, tok_c, pos0 + base,
                                   n_live, ctx, block_tables=eff_tables,
                                   block_size=block_size, capacity=capacity)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        done_here = (n_tokens > base) & (n_tokens <= base + chunk)
        first = jnp.where(done_here, nxt, first)
        return (cache, first), None

    (cache, first), _ = jax.lax.scan(
        step, (cache, first0), jnp.arange(num_steps, dtype=jnp.int32))
    return first, cache


def mixed_loop(cfg: ModelConfig, params: Dict, cache: Dict,
               tokens: jax.Array, pos: jax.Array, steps_left: jax.Array,
               sfx_tokens: jax.Array, sfx_pos0: jax.Array,
               sfx_n: jax.Array, ctx: RunContext, *,
               block_tables: jax.Array, sfx_tables: jax.Array,
               block_size: int, chunk: int, num_steps: int, capacity: int):
    """Unified mixed prefill/decode engine step: ONE scan, ONE dispatch.

    Fuses a :func:`decode_loop` window over the decode cohort (tokens
    (S, 1) / pos / steps_left / block_tables, exactly decode_loop's
    contract) with :func:`prefill_chunks` over joining rows (sfx_tokens
    (J, T) / sfx_pos0 / sfx_n / sfx_tables), so a mid-flight join or
    preemption restore never stalls the decode cohort behind a separate
    prefill dispatch (docs/architecture.md ADR-005).  Each scan step runs
    the pending prefill chunk first, then the decode step — matching the
    serial order (prefill_into -> suffix scan -> decode window) the split
    path executes, over *disjoint* physical blocks: suffix rows write only
    their own freshly-allocated blocks, so the decode tile's inputs are
    bitwise identical to the split path's.

    Prefill rows take no part in sampling (their ``firsts`` come from the
    teacher-forced chunk logits); decode rows take no part in chunk writes.
    ``num_steps`` covers the longer of the two tiles: a tile past its end
    runs dead (trash-block writes, frozen tokens).

    Returns (tokens_out (S, num_steps), firsts (J,), new_cache).
    """
    tok0 = tokens[:, 0].astype(jnp.int32)
    tables = block_tables.astype(jnp.int32)
    steps_left = steps_left.astype(jnp.int32)
    stables = sfx_tables.astype(jnp.int32)
    sfx_n = sfx_n.astype(jnp.int32)
    sfx_pos0 = sfx_pos0.astype(jnp.int32)
    stoks = sfx_tokens.astype(jnp.int32)
    pad = num_steps * chunk - stoks.shape[1]
    if pad > 0:
        stoks = jnp.pad(stoks, ((0, 0), (0, pad)))
    first0 = jnp.zeros((stoks.shape[0],), jnp.int32)

    def step(carry, t):
        cache, tok, cur, first = carry
        # --- prefill chunk tile (joining rows) ---
        base = t * chunk
        tok_c = jax.lax.dynamic_slice_in_dim(stoks, base, chunk, axis=1)
        n_live = jnp.clip(sfx_n - base, 0, chunk)
        eff_stables = jnp.where((n_live > 0)[:, None], stables, 0)
        logits_c, cache = chunk_step(cfg, params, cache, tok_c,
                                     sfx_pos0 + base, n_live, ctx,
                                     block_tables=eff_stables,
                                     block_size=block_size,
                                     capacity=capacity)
        nxt_c = jnp.argmax(logits_c, -1).astype(jnp.int32)
        done_here = (sfx_n > base) & (sfx_n <= base + chunk)
        first = jnp.where(done_here, nxt_c, first)
        # --- decode tile (resident cohort) ---
        live = t < steps_left
        eff_tables = jnp.where(live[:, None], tables, 0)
        eff_pos = jnp.where(live, jnp.minimum(cur, capacity - 1), 0)
        logits, cache = decode_step(cfg, params, cache, tok[:, None],
                                    eff_pos, ctx, block_tables=eff_tables,
                                    block_size=block_size)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        nxt = jnp.where(live, nxt, tok)
        cur = jnp.where(live, jnp.minimum(cur + 1, capacity), cur)
        return (cache, nxt, cur, first), nxt

    (cache, _, _, first), toks = jax.lax.scan(
        step, (cache, tok0, pos.astype(jnp.int32), first0),
        jnp.arange(num_steps, dtype=jnp.int32))
    return jnp.swapaxes(toks, 0, 1), first, cache


def verify_window(cfg: ModelConfig, params: Dict, cache: Dict,
                  tokens: jax.Array, pos0: jax.Array, n_live: jax.Array,
                  ctx: RunContext, *, block_tables: jax.Array,
                  block_size: int, capacity: int):
    """Speculative verification: score a K+1 token window in ONE dispatch.

    The target-model half of cross-tier speculative decoding
    (docs/architecture.md ADR-008).  Row i of ``tokens`` (B, C) is
    ``[t0, d_1 .. d_k, pad...]`` — the slot's current token followed by
    ``k_i`` draft proposals — of which the first ``n_live[i] = k_i + 1``
    are fed, teacher-forced, at positions ``pos0[i] .. pos0[i]+k_i``
    through the ``chunk_step`` machinery (one chunked model pass: paged
    KV writes through ``block_tables``, per-row variable-length causal
    masking in the GQA-fused ``paged_prefill`` kernel).  Unlike
    ``chunk_step`` it unembeds EVERY position, returning the greedy token
    grid (B, C): ``greedy[i, j]`` is the target's next token after
    feeding ``tokens[i, j]`` — bitwise the same computation as ``j+1``
    stepwise :func:`decode_step` calls, because chunk-mode attention is
    write-then-attend with the same ``capacity - 1`` clamp.

    Acceptance happens on the host (:func:`spec_accept`): with
    ``a = accepts[i]`` draft tokens accepted, the emitted tokens are
    ``greedy[i, :a+1]`` (each accepted draft token equals the greedy
    token before it, so the greedy row IS the decoded continuation), the
    new current token is ``greedy[i, a]``, and the cursor advances by
    ``a + 1``.  Rejected positions ``pos0+a+1 .. pos0+k`` hold stale KV:
    harmless, because every later dispatch either overwrites a position
    before attending to it (decode and chunk modes both write first) or
    causally masks it (``k_pos <= pos0 + q``), exactly the chunked-
    prefill containment argument of ADR-005.  Callers must clamp
    ``k_i <= capacity - pos0[i] - 1`` so no window write needs the
    ``capacity - 1`` pin (a pinned write would collapse last-live-wins
    instead of last-step-wins and break stepwise equivalence);
    ``n_live = 0`` rows are dead (trash-block parking, caller masks).

    Returns (greedy (B, C) int32, new_cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    toks = tokens.astype(jnp.int32)
    x = jnp.take(params["embed"], toks, axis=0).astype(dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    c = x.shape[1]
    pos0 = pos0.astype(jnp.int32)
    n_live = n_live.astype(jnp.int32)
    eff_tables = jnp.where((n_live > 0)[:, None],
                           block_tables.astype(jnp.int32), 0)
    positions = jnp.minimum(pos0[:, None] + jnp.arange(c), capacity - 1)
    rope = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    x, new_cache, _ = apply_stack(cfg, params, x, ctx, rope, cache, "chunk",
                                  prefix_len=0, pos=(pos0, n_live),
                                  cache_capacity=capacity,
                                  block_tables=eff_tables,
                                  block_size=block_size)
    logits = unembed(cfg, params, x, ctx)                     # (B, C, V)
    return jnp.argmax(logits, -1).astype(jnp.int32), new_cache


def draft_loop(cfg: ModelConfig, params: Dict, cache: Dict,
               ctoks: jax.Array, cpos0: jax.Array, n_ctok: jax.Array,
               tokens: jax.Array, pos: jax.Array, k_live: jax.Array,
               ctx: RunContext, *, block_tables: jax.Array,
               block_size: int, catchup_steps: int, num_steps: int,
               capacity: int):
    """Draft side of speculative decoding: catch-up + K greedy steps.

    Runs on the *draft* model (a reduced-cost config sharing the target's
    vocab) against the draft's own paged pool, indexed by the SAME block
    tables as the target (ADR-008: the draft pool mirrors the target's
    block geometry, so no extra host bookkeeping).  Two phases under one
    jitted dispatch:

    1. **Catch-up** (``catchup_steps > 0``): teacher-force ``ctoks``
       (B, Tc) — committed target tokens the draft has not yet ingested —
       at positions ``cpos0[i] ..`` via :func:`prefill_loop`.  After a
       partial accept this is empty; after a full accept it is one token;
       after admit/restore/migration it replays the whole history.  One
       uniform resync path subsumes every case.
    2. **Draft**: ``k_live[i]`` greedy steps from the current token via
       :func:`decode_loop` (dead rows freeze and park in the trash
       block), emitting the proposals ``verify_window`` scores.

    Returns (drafts (B, num_steps) int32, new_cache).
    """
    if catchup_steps > 0:
        _, cache = prefill_loop(cfg, params, cache, ctoks, cpos0, n_ctok,
                                ctx, block_tables=block_tables,
                                block_size=block_size,
                                num_steps=catchup_steps, capacity=capacity)
    return decode_loop(cfg, params, cache, tokens, pos, k_live, ctx,
                       block_tables=block_tables, block_size=block_size,
                       num_steps=num_steps, capacity=capacity)


def spec_accept(greedy: np.ndarray, drafts: np.ndarray,
                n_spec: np.ndarray) -> np.ndarray:
    """Longest-matching-prefix acceptance rule (host side, numpy).

    greedy: (B, C >= K+1) verify_window output; drafts: (B, K) draft
    proposals; n_spec: (B,) live draft count per row (0..K).  Row i
    accepts ``a`` draft tokens where ``a`` is the length of the longest
    prefix with ``drafts[i, j] == greedy[i, j]`` for all ``j < a``
    (draft token ``d_{j+1}`` is accepted iff it equals the target's
    greedy choice after the previous token).  Lossless by construction:
    emitted tokens ``greedy[i, :a+1]`` are exactly what ``a + 1``
    stepwise greedy decode steps would produce.

    Returns accepts (B,) int: accepted draft-token count per row.
    """
    k = drafts.shape[1]
    m = (np.asarray(greedy)[:, :k] == np.asarray(drafts))
    m &= np.arange(k)[None, :] < np.asarray(n_spec)[:, None]
    return np.cumprod(m, axis=1).sum(axis=1).astype(np.int64)
