"""RWKV-6 "Finch" block: data-dependent-decay WKV recurrence + channel mix.

Reference path: the chunked-parallel WKV evaluation below (numerically stable:
all decay products are <= 1).  The TPU hot path is the Pallas kernel in
``repro.kernels.rwkv6_scan`` validated against :func:`wkv6_chunked_ref`.

Recurrence (per head, state S in R^{N_k x N_v}):
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(w0 + tanh(x_w A) B)) data-dependent per channel.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.context import RunContext
from repro.models.spec import ParamSpec

_LORA_RANK = 64
_CHUNK = 64


def rwkv_time_specs(cfg: ModelConfig):
    d, h, n = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "mu": ParamSpec((5, d), (None, "embed"), jnp.float32, init="zeros"),
        "w0": ParamSpec((d,), ("embed",), jnp.float32, init="zeros"),
        "wA": ParamSpec((d, _LORA_RANK), ("embed", "rank")),
        "wB": ParamSpec((_LORA_RANK, d), ("rank", "embed"), fan_in=_LORA_RANK),
        "u": ParamSpec((h, n), ("heads", "head_dim"), jnp.float32,
                       init="zeros"),
        "wr": ParamSpec((d, h, n), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, h, n), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, h, n), ("embed", "heads", "head_dim")),
        "wg": ParamSpec((d, h, n), ("embed", "heads", "head_dim")),
        "gn_scale": ParamSpec((h, n), ("heads", "head_dim"), init="ones"),
        "gn_bias": ParamSpec((h, n), ("heads", "head_dim"), init="zeros"),
        "wo": ParamSpec((h, n, d), ("heads", "head_dim", "embed"),
                        fan_in=h * n),
    }


def rwkv_channel_specs(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), ("embed",), jnp.float32, init="zeros"),
        "mu_r": ParamSpec((d,), ("embed",), jnp.float32, init="zeros"),
        "wk": ParamSpec((d, f), ("embed", "mlp")),
        "wv": ParamSpec((f, d), ("mlp", "embed"), fan_in=f),
        "wr": ParamSpec((d, d), ("embed", "embed_out")),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} with the carried last token (or zeros) at t=0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv6_chunked_ref(r, k, v, w, u, s0, chunk: int = _CHUNK,
                     unroll: bool = False):
    """Chunked-parallel WKV. r,k,v,w: (B,S,H,N) — w is the decay in (0,1].

    Returns y: (B,S,H,N), s_final: (B,H,N,N) fp32.
    All decay factors appearing in products are <=1 => numerically stable.
    """
    b, s, h, n = r.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    m = s // c
    f32 = jnp.float32
    rs, ks, vs, ws = (a.astype(f32).reshape(b, m, c, h, n) for a in (r, k, v, w))
    lw = jnp.log(jnp.maximum(ws, 1e-30))
    cum_incl = jnp.cumsum(lw, axis=2)                 # log prod_{1..t}
    cum_excl = cum_incl - lw                          # log prod_{1..t-1}
    total = jnp.exp(cum_incl[:, :, -1])               # (B,M,H,N)

    # ---- intra-chunk: scan over the C positions, vectorized over chunks ----
    def step(S, xs):
        r_t, k_t, v_t, w_t = xs                       # (B,M,H,N)
        bonus = jnp.einsum("bmhk,hk,bmhk->bmh", r_t, u.astype(f32), k_t)
        y = jnp.einsum("bmhk,bmhkv->bmhv", r_t, S) + bonus[..., None] * v_t
        S = w_t[..., None] * S + k_t[..., None] * v_t[..., None, :]
        return S, y

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (rs, ks, vs, ws))
    # analysis mode unrolls so cost_analysis sees all C steps (the inter-
    # chunk scan is ~2% of the FLOPs and stays rolled)
    delta, y_intra = jax.lax.scan(step, jnp.zeros((b, m, h, n, n), f32), xs,
                                  unroll=c if unroll else 1)
    y_intra = jnp.moveaxis(y_intra, 0, 2)             # (B,M,C,H,N)

    # ---- inter-chunk: propagate state across chunks (M sequential steps) ----
    def step2(S, xs):
        tot, dlt = xs                                 # (B,H,N), (B,H,N,N)
        return tot[..., None] * S + dlt, S

    s0 = jnp.zeros((b, h, n, n), f32) if s0 is None else s0.astype(f32)
    s_final, s_prefix = jax.lax.scan(
        step2, s0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(delta, 1, 0)))
    s_prefix = jnp.moveaxis(s_prefix, 0, 1)           # (B,M,H,N,N)

    # ---- prefix-state contribution ----
    rq = rs * jnp.exp(cum_excl)                       # decays <= 1
    y = y_intra + jnp.einsum("bmchk,bmhkv->bmchv", rq, s_prefix)
    return y.reshape(b, s, h, n).astype(r.dtype), s_final


def wkv6_step(r, k, v, w, u, s0):
    """Single decode step. r,k,v,w: (B,1,H,N); s0: (B,H,N,N) fp32."""
    f32 = jnp.float32
    r_, k_, v_, w_ = (a.astype(f32)[:, 0] for a in (r, k, v, w))
    bonus = jnp.einsum("bhk,hk,bhk->bh", r_, u.astype(f32), k_)
    y = jnp.einsum("bhk,bhkv->bhv", r_, s0) + bonus[..., None] * v_
    s1 = w_[..., None] * s0 + k_[..., None] * v_[..., None, :]
    return y[:, None].astype(r.dtype), s1


def _group_norm(y: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    """Per-head layer norm. y: (B,S,H,N)."""
    f = y.astype(jnp.float32)
    mu = jnp.mean(f, -1, keepdims=True)
    var = jnp.var(f, -1, keepdims=True)
    out = (f - mu) * jax.lax.rsqrt(var + 1e-5)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(y.dtype)


def rwkv_time_apply(params: dict, x: jax.Array, cfg: ModelConfig,
                    ctx: RunContext, cache: Optional[dict], mode: str):
    """Time-mix. cache = {"prev": (B,D), "s": (B,H,N,N) f32}."""
    b, s, d = x.shape
    h, n = cfg.n_heads, cfg.head_dim
    prev = cache["prev"] if cache is not None else None
    xp = _token_shift(x, prev) if mode != "decode" else (
        prev[:, None].astype(x.dtype) if prev is not None
        else jnp.zeros_like(x))
    mu = params["mu"].astype(x.dtype)
    mixed = [x + (xp - x) * mu[i] for i in range(5)]  # r,k,v,g,w
    xr, xk, xv, xg, xw = mixed

    def proj(inp, wname):
        return jnp.einsum("bsd,dhn->bshn", inp, params[wname],
                          preferred_element_type=jnp.float32).astype(x.dtype)

    r, k, v = proj(xr, "wr"), proj(xk, "wk"), proj(xv, "wv")
    g = jax.nn.silu(proj(xg, "wg").astype(jnp.float32)).astype(x.dtype)
    lora = jnp.einsum("bsr,rd->bsd",
                      jnp.tanh(jnp.einsum("bsd,dr->bsr",
                                          xw.astype(jnp.float32),
                                          params["wA"].astype(jnp.float32))),
                      params["wB"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(params["w0"].astype(jnp.float32) + lora))
    w = w.reshape(b, s, h, n)

    s0 = cache["s"] if cache is not None else None
    if mode == "decode":
        y, s_new = wkv6_step(r, k, v, w, params["u"], s0)
    elif ctx.impl == "pallas":
        from repro.kernels import ops as kops
        y, s_new = kops.rwkv6_scan(r, k, v, w, params["u"], s0=s0)
    else:
        y, s_new = wkv6_chunked_ref(r, k, v, w, params["u"], s0,
                                    unroll=ctx.scan_unroll)

    y = _group_norm(y, params["gn_scale"], params["gn_bias"]) * g
    out = jnp.einsum("bshn,hnd->bsd", y, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = None
    if cache is not None or mode == "prefill":
        new_cache = {"prev": x[:, -1].astype(jnp.float32), "s": s_new}
    return out, new_cache


def rwkv_channel_apply(params: dict, x: jax.Array, cfg: ModelConfig,
                       cache: Optional[dict], mode: str):
    """Channel-mix. cache = {"prev": (B,D)}."""
    prev = cache["prev"] if cache is not None else None
    xp = _token_shift(x, prev) if mode != "decode" else (
        prev[:, None].astype(x.dtype) if prev is not None
        else jnp.zeros_like(x))
    mu_k = params["mu_k"].astype(x.dtype)
    mu_r = params["mu_r"].astype(x.dtype)
    xk = x + (xp - x) * mu_k
    xr = x + (xp - x) * mu_r
    kk = jnp.einsum("bsd,df->bsf", xk, params["wk"],
                    preferred_element_type=jnp.float32)
    kk = jnp.square(jax.nn.relu(kk)).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, params["wv"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, params["wr"],
                   preferred_element_type=jnp.float32))
    out = (rr * vv.astype(jnp.float32)).astype(x.dtype)
    new_cache = None
    if cache is not None or mode == "prefill":
        new_cache = {"prev": x[:, -1].astype(jnp.float32)}
    return out, new_cache


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    h, n, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {
        "tm": {"prev": jnp.zeros((batch, d), jnp.float32),
               "s": jnp.zeros((batch, h, n, n), jnp.float32)},
        "cm": {"prev": jnp.zeros((batch, d), jnp.float32)},
    }
