"""RunContext: everything the model needs to know about the runtime substrate."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class RunContext:
    """Static execution context threaded through model apply functions.

    mesh=None means single-device execution (smoke tests, local venue) — all
    distributed code paths (shard_map MoE, FSDP gathers) degrade to local math.
    """

    mesh: Optional[jax.sharding.Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)       # ("pod","data") when multi-pod
    model_axis: str = "model"
    impl: str = "xla"                          # xla | pallas
    remat: str = "full"                        # none | dots | full
    # paged decode kernel: GQA-fused flash-decoding grid (B, Hkv, M) vs the
    # per-query-head grid (B, Hq, M) — kept only as the A/B baseline
    paged_fused: bool = True
    moe_capacity_factor: float = 1.25
    # hillclimb knobs (see EXPERIMENTS.md §Perf)
    seq_shard_attn: bool = False               # sequence-parallel attention
    loss_chunk: int = 0                        # 0 = unchunked cross-entropy
    # analysis: fully unroll the layer scan so cost_analysis sees every layer
    scan_unroll: bool = False
    # gradient accumulation: split the global batch into k microbatches
    microbatches: int = 1
    # "tp" (default: Megatron TP + FSDP) | "zero-sp" (weights FSDP-only,
    # sequence sharded over the model axis; dense archs, prefill/decode)
    sharding_profile: str = "tp"

    @property
    def zero_sp(self) -> bool:
        return self.sharding_profile == "zero-sp"

    @property
    def fsdp_weights(self) -> bool:
        # serving lowers with weights resident (no optimizer state): no
        # per-layer FSDP gathers on the decode path
        return self.sharding_profile != "serve"

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    def dp_spec(self):
        """PartitionSpec entry for the batch dim."""
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
