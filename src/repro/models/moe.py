"""Mixture-of-Experts layer: top-k routing + sort-based capacity dispatch.

Distribution strategy (DESIGN.md §4):
 - routing (router matmul, top-k, load-balance aux) runs in plain GSPMD land;
 - dispatch/compute/combine runs inside ``shard_map``:
     * EP mode (n_experts divisible by the model axis, e.g. OLMoE 64e/16):
       experts sharded over "model"; each shard dispatches its own experts'
       assignments; one psum over "model" combines.
     * TP mode (Mixtral 8e < 16): every shard holds all experts but only a
       slice of d_ff; psum over "model" after the down-projection.
   Expert weights are additionally FSDP-sharded over "data" on the d_model dim
   and all-gathered (tiled) on entry — backward becomes reduce-scatter.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.context import RunContext
from repro.models.layers import _ACTS
from repro.models.spec import ParamSpec


def moe_specs(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    sp = {
        "router": ParamSpec((d, e), ("embed", "experts_r"), jnp.float32),
        "wi": ParamSpec((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "wo": ParamSpec((e, f, d), ("experts", "mlp", "embed"), fan_in=f),
    }
    if cfg.mlp_gated:
        sp["wg"] = ParamSpec((e, d, f), ("experts", "embed", "mlp"), fan_in=d)
    return sp


def _route(x2d: jax.Array, router: jax.Array, cfg: ModelConfig):
    """x2d: (T, D) -> weights (T,k), ids (T,k), aux-loss scalar."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)
    # Mixtral renormalizes over the top-k; OLMoE does not.
    if cfg.name.startswith("mixtral"):
        weights = weights / (jnp.sum(weights, -1, keepdims=True) + 1e-9)
    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e
    t = x2d.shape[0]
    counts = jnp.zeros((cfg.n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f_e = counts / (t * cfg.top_k)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(f_e * p_e)
    return weights, ids, aux


def _capacity(t: int, cfg: ModelConfig, factor: float) -> int:
    cap = int(t * cfg.top_k / cfg.n_experts * factor)
    return max(8, -(-cap // 8) * 8)


def _dispatch_compute_combine(x2d, weights, ids, wi, wg, wo, *, cfg: ModelConfig,
                              e_offset, e_local: int, capacity: int):
    """Sort-based capacity dispatch on a single shard.

    x2d: (T, D); ids: (T, k) global expert ids; wi: (e_local, D, F) etc.
    Returns this shard's partial output (T, D).
    """
    t, d = x2d.shape
    k = ids.shape[-1]
    flat_ids = ids.reshape(-1)
    sort_idx = jnp.argsort(flat_ids, stable=True)
    s_ids = flat_ids[sort_idx]
    seg_start = jnp.searchsorted(s_ids, s_ids, side="left")
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    local = (s_ids >= e_offset) & (s_ids < e_offset + e_local) \
        & (pos_in_e < capacity)
    b_e = jnp.where(local, s_ids - e_offset, e_local)      # OOB row -> dropped
    b_c = jnp.where(local, pos_in_e, capacity)
    tok = sort_idx // k

    buf = jnp.zeros((e_local, capacity, d), x2d.dtype)
    buf = buf.at[b_e, b_c].set(x2d[tok], mode="drop")

    act = _ACTS[cfg.mlp_act]
    h = jnp.einsum("ecd,edf->ecf", buf, wi, preferred_element_type=jnp.float32)
    if wg is not None:
        g = jnp.einsum("ecd,edf->ecf", buf, wg,
                       preferred_element_type=jnp.float32)
        h = act(g) * h
    else:
        h = act(h)
    y_buf = jnp.einsum("ecf,efd->ecd", h.astype(x2d.dtype), wo,
                       preferred_element_type=jnp.float32).astype(x2d.dtype)

    y_assign = y_buf.at[b_e, b_c].get(mode="fill", fill_value=0)  # (T*k, D)
    wflat = weights.reshape(-1)[sort_idx].astype(y_assign.dtype)
    y = jnp.zeros((t, d), x2d.dtype).at[tok].add(y_assign * wflat[:, None])
    return y


def _sharded_body(x, weights, ids, wi, wg, wo, *, cfg: ModelConfig, ep: bool,
                  model_axis: str, gated: bool, capacity: int,
                  fsdp: bool = True):
    """shard_map body. x: (B_loc, S, D) replicated over model axis.

    fsdp=True (training): expert weights FSDP over "data", gathered on entry
    (backward becomes reduce-scatter).  fsdp=False (serving): weights stay
    resident 2-D sharded; the up-projection contracts a *sliced* d_model dim
    with a tiny psum over "data" — no per-step weight gathers at all.
    """
    wg = wg if gated else None
    if fsdp:
        wi = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        if wg is not None:
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    if ep:
        e_local = wi.shape[0]
        e_offset = jax.lax.axis_index(model_axis) * e_local
    else:
        e_local, e_offset = cfg.n_experts, 0
    if fsdp:
        y = _dispatch_compute_combine(
            x2d, weights.reshape(b * s, -1), ids.reshape(b * s, -1),
            wi, wg, wo, cfg=cfg, e_offset=e_offset, e_local=e_local,
            capacity=capacity)
    else:
        y = _dispatch_contract_sharded(
            x2d, weights.reshape(b * s, -1), ids.reshape(b * s, -1),
            wi, wg, wo, cfg=cfg, e_offset=e_offset, e_local=e_local,
            capacity=capacity)
    y = jax.lax.psum(y, model_axis)
    return y.reshape(b, s, d)


def _dispatch_contract_sharded(x2d, weights, ids, wi, wg, wo, *,
                               cfg: ModelConfig, e_offset, e_local: int,
                               capacity: int):
    """Serving MoE: wi/wg hold a d_model slice (sharded over "data");
    up-projection partial sums are psum'd over "data" before the
    nonlinearity; wo is resident with full d_model output."""
    t, d = x2d.shape
    k = ids.shape[-1]
    d_loc = wi.shape[1]
    didx = jax.lax.axis_index("data")
    x_slice = jax.lax.dynamic_slice_in_dim(x2d, didx * d_loc, d_loc, 1)

    flat_ids = ids.reshape(-1)
    sort_idx = jnp.argsort(flat_ids, stable=True)
    s_ids = flat_ids[sort_idx]
    seg_start = jnp.searchsorted(s_ids, s_ids, side="left")
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    local = (s_ids >= e_offset) & (s_ids < e_offset + e_local) \
        & (pos_in_e < capacity)
    b_e = jnp.where(local, s_ids - e_offset, e_local)
    b_c = jnp.where(local, pos_in_e, capacity)
    tok = sort_idx // k

    buf = jnp.zeros((e_local, capacity, d_loc), x2d.dtype)
    buf = buf.at[b_e, b_c].set(x_slice[tok], mode="drop")

    act = _ACTS[cfg.mlp_act]
    h = jnp.einsum("ecd,edf->ecf", buf, wi,
                   preferred_element_type=jnp.float32)
    h = jax.lax.psum(h, "data")                 # complete the d contraction
    if wg is not None:
        g = jnp.einsum("ecd,edf->ecf", buf, wg,
                       preferred_element_type=jnp.float32)
        g = jax.lax.psum(g, "data")
        h = act(g) * h
    else:
        h = act(h)
    y_buf = jnp.einsum("ecf,efd->ecd", h.astype(x2d.dtype), wo,
                       preferred_element_type=jnp.float32).astype(x2d.dtype)
    y_assign = y_buf.at[b_e, b_c].get(mode="fill", fill_value=0)
    wflat = weights.reshape(-1)[sort_idx].astype(y_assign.dtype)
    y = jnp.zeros((t, d), x2d.dtype).at[tok].add(y_assign * wflat[:, None])
    return y


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              ctx: RunContext) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    weights, ids, aux = _route(x.reshape(b * s, d), params["router"], cfg)
    weights = weights.reshape(b, s, -1)
    ids = ids.reshape(b, s, -1)
    wg = params.get("wg")

    if ctx.mesh is None:
        cap = _capacity(b * s, cfg, ctx.moe_capacity_factor)
        y = _dispatch_compute_combine(
            x.reshape(b * s, d), weights.reshape(b * s, -1),
            ids.reshape(b * s, -1), params["wi"], wg, params["wo"], cfg=cfg,
            e_offset=0, e_local=cfg.n_experts, capacity=cap)
        return y.reshape(b, s, d), aux

    ep = cfg.n_experts % ctx.model_size == 0
    m = ctx.model_axis
    # Tokens replicate when the batch can't shard (e.g. long_500k batch=1).
    dp = ctx.dp_spec() if b % ctx.dp_size == 0 else None
    b_loc = b // ctx.dp_size if dp is not None else b
    # capacity is per-shard: local tokens routed into the global expert pool
    cap = _capacity(b_loc * s, cfg, ctx.moe_capacity_factor)
    fsdp = ctx.fsdp_weights
    if ep:
        # training: wi (E->m, D->data FSDP, F); serving: same 2-D sharding
        # but contraction-sharded compute (no gathers); wo output dim full
        w_specs = dict(wi=P(m, "data", None),
                       wo=P(m, None, "data" if fsdp else None))
    else:
        w_specs = dict(wi=P(None, "data", m),
                       wo=P(None, m, "data" if fsdp else None))
    in_specs = (P(dp, None, None), P(dp, None, None), P(dp, None, None),
                w_specs["wi"], w_specs["wi"], w_specs["wo"])
    body = functools.partial(_sharded_body, cfg=cfg, ep=ep, model_axis=m,
                             gated=cfg.mlp_gated, capacity=cap, fsdp=fsdp)
    from repro.distributed.sharding import shard_map_compat
    y = shard_map_compat(
        body, mesh=ctx.mesh, in_specs=in_specs,
        out_specs=P(dp, None, None),
    )(x, weights, ids, params["wi"],
      wg if wg is not None else params["wi"], params["wo"])
    return y, aux
