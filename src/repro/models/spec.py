"""Parameter specs: one tree describing shape, dtype, logical axes and init.

``param_specs(cfg)`` is the single source of truth from which we derive
 - real initialized parameters (``init_params``),
 - zero-allocation ``ShapeDtypeStruct`` stand-ins (``abstract_params``),
 - logical sharding axes (``logical_axes``) consumed by
   ``repro.distributed.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]    # logical axis name per dim (or None)
    dtype: Any = jnp.bfloat16
    init: str = "fan_in"               # fan_in | zeros | ones
    fan_in: Optional[int] = None       # override for fan_in init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array):
    """Materialize real parameters (used by smoke tests / real training)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            fan = s.fan_in if s.fan_in is not None else (s.shape[0] if s.shape else 1)
            std = 1.0 / np.sqrt(max(fan, 1))
            out.append((jax.random.normal(k, s.shape, jnp.float32) * std)
                       .astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs):
    """ShapeDtypeStruct tree — no device allocation (dry-run path)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec)


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in
               jax.tree.leaves(specs, is_leaf=_is_spec))


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Add a leading stacked-layer dim to every spec in the tree."""
    def f(s: ParamSpec) -> ParamSpec:
        fan = s.fan_in if s.fan_in is not None else (s.shape[0] if s.shape else 1)
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.dtype,
                         s.init, fan)
    return jax.tree.map(f, spec_tree, is_leaf=_is_spec)
