"""Pure-jnp oracles for every Pallas kernel (the allclose targets in tests).

These are *independent* straight-line implementations — deliberately naive —
so that kernel bugs can't hide behind shared code with the model reference
paths (which are themselves validated against these in tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window: Optional[int] = None,
                        softcap: Optional[float] = None):
    """q: (B,Hq,S,D); k,v: (B,Hkv,T,D) -> (B,Hq,S,D). Materializes scores."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * (d ** -0.5)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(k.shape[2])[None, :]
    ok = jnp.ones((s, k.shape[2]), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    scores = jnp.where(ok, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_tables, context_lens, *,
                        softcap: Optional[float] = None):
    """Decode-time paged attention oracle (block-table gather, materialized).

    q: (B, Hq, D) one query per slot; k_pool, v_pool: (N, bs, Hkv, D) the
    shared KV block pool; block_tables: (B, M) int32 physical block ids in
    logical order; context_lens: (B,) int32 tokens written per slot (the
    per-slot cursor + 1).  Returns (B, Hq, D).  Positions >= context_lens[i]
    (including every slot of an unused table entry) are masked out, so stale
    pool contents can never leak into a slot's output.

    This is the single oracle for *both* paged kernel grids — the per-head
    (B, Hq, M) kernel and the GQA-fused flash-decoding (B, Hkv, M) kernel —
    because fusion only changes how often a KV block is staged, never the
    math; tests assert both against it (tests/test_kernels.py).
    """
    b, hq, d = q.shape
    _, bs, hkv, _ = k_pool.shape
    g = hq // hkv
    k = k_pool[block_tables].reshape(b, -1, hkv, d)      # (B, M*bs, Hkv, D)
    v = v_pool[block_tables].reshape(b, -1, hkv, d)
    kk = jnp.repeat(jnp.swapaxes(k, 1, 2), g, axis=1)    # (B, Hq, M*bs, D)
    vv = jnp.repeat(jnp.swapaxes(v, 1, 2), g, axis=1)
    scores = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * (d ** -0.5)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    ok = jnp.arange(k.shape[1])[None, :] < context_lens[:, None]   # (B, M*bs)
    scores = jnp.where(ok[:, None, :], scores, -1e30)
    # re-mask after softmax: for a live row this is exact (masked probs
    # underflow to 0.0), while a context_lens==0 row — where softmax
    # degrades to uniform over pure garbage — goes to all-zero output,
    # matching the kernel's zero accumulator
    probs = jax.nn.softmax(scores, axis=-1) * ok[:, None, :]
    out = jnp.einsum("bhk,bhkd->bhd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_prefill_ref(q, k_pool, v_pool, block_tables, pos0, n_live, *,
                      softcap: Optional[float] = None):
    """Chunked-prefill paged attention oracle (block-table gather).

    q: (B, Hq, C, D) one C-token suffix chunk per slot; k_pool, v_pool:
    (N, bs, Hkv, D) the shared KV block pool (the chunk's own KV already
    scattered in); block_tables: (B, M) int32; pos0: (B,) int32 absolute
    position of each chunk's first token; n_live: (B,) int32 live tokens
    per chunk (0..C).  Returns (B, Hq, C, D).  Chunk position t attends to
    key positions <= pos0 + t (resident prefix + intra-chunk causal); rows
    with t >= n_live — including every row of an n_live==0 slot — are
    re-masked to exact zero after the softmax, matching the kernel's
    zeroed accumulator for dead rows.
    """
    b, hq, c, d = q.shape
    _, bs, hkv, _ = k_pool.shape
    g = hq // hkv
    k = k_pool[block_tables].reshape(b, -1, hkv, d)      # (B, M*bs, Hkv, D)
    v = v_pool[block_tables].reshape(b, -1, hkv, d)
    kk = jnp.repeat(jnp.swapaxes(k, 1, 2), g, axis=1)    # (B, Hq, M*bs, D)
    vv = jnp.repeat(jnp.swapaxes(v, 1, 2), g, axis=1)
    scores = jnp.einsum("bhcd,bhkd->bhck", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * (d ** -0.5)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = pos0[:, None] + jnp.arange(c)[None, :]               # (B, C)
    k_pos = jnp.arange(k.shape[1])                               # (M*bs,)
    ok = (jnp.arange(c)[None, :] < n_live[:, None])[:, :, None] \
        & (k_pos[None, None, :] <= q_pos[:, :, None])            # (B, C, K)
    scores = jnp.where(ok[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1) * ok[:, None]
    out = jnp.einsum("bhck,bhkd->bhcd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def quantize_kv_blocks_ref(blocks):
    """Loop-form oracle for per-(block, head) int8 KV quantization.

    Quantizes each (block, head) slice independently with its own scale
    ``max|x| / 127``; blocks without a head axis (ndim < 3) get one scale
    per block.  Returns (q int8, scales float32 keepdims), matching
    ``ops.quantize_kv_blocks``.
    """
    import numpy as np
    v = np.asarray(blocks, dtype=np.float32)
    if v.ndim >= 3:
        axes = tuple(i for i in range(1, v.ndim) if i != v.ndim - 2)
    else:
        axes = tuple(range(1, v.ndim))
    amax = np.max(np.abs(v), axis=axes, keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = np.clip(np.round(v / scale), -127, 127).astype(np.int8)
    return jnp.asarray(q), jnp.asarray(scale.astype(np.float32))


def dequantize_kv_blocks_ref(q, scale, dtype=jnp.bfloat16):
    """Oracle inverse of :func:`quantize_kv_blocks_ref`."""
    import numpy as np
    out = np.asarray(q, dtype=np.float32) * np.asarray(scale,
                                                       dtype=np.float32)
    return jnp.asarray(out).astype(dtype)


def rglru_scan_ref(a, b, h0):
    """Sequential linear recurrence. a, b: (B,S,R); h0: (B,R) fp32."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t.astype(jnp.float32) * h + b_t.astype(jnp.float32)
        return h, h

    a_t = jnp.moveaxis(a, 1, 0)
    b_t = jnp.moveaxis(b, 1, 0)
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype), h_last


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """Sequential WKV. r,k,v,w: (B,H,S,N); u: (H,N); s0: (B,H,N,N) fp32."""
    f32 = jnp.float32

    def step(s, xs):
        r_t, k_t, v_t, w_t = (x.astype(f32) for x in xs)   # (B,H,N)
        bonus = jnp.einsum("bhk,hk,bhk->bh", r_t, u.astype(f32), k_t)
        y = jnp.einsum("bhk,bhkn->bhn", r_t, s) + bonus[..., None] * v_t
        s = w_t[..., None] * s + k_t[..., None] * v_t[..., None, :]
        return s, y

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0.astype(f32), xs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype), s_last
