"""RWKV-6 WKV recurrence Pallas TPU kernel.

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (per head, S in R^{N x N})

TPU adaptation (DESIGN.md §6): the (N x N) per-head state is pinned in VMEM
scratch for the whole sequence; r/k/v/w stream through VMEM in (C x N) chunk
tiles over a sequential grid dimension.  Each step inside a chunk is a rank-1
update + matvec against the resident state — N = 64 maps onto half an MXU
tile, and the state never round-trips to HBM (the GPU formulation re-loads it
per thread-block).  A fully-parallel intra-chunk matmul form exists but is
numerically unstable for unclamped RWKV decays (exp(-cum log w) overflows
fp32); the state-resident chunked scan below is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sn_ref, s_ref,
            *, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)      # (C, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)         # (N,)

    def step(s, xs):
        r_t, k_t, v_t, w_t = xs              # (N,)
        bonus = jnp.sum(r_t * u * k_t)
        y_t = r_t @ s + bonus * v_t
        s = w_t[:, None] * s + k_t[:, None] * v_t[None, :]
        return s, y_t

    s_last, ys = jax.lax.scan(step, s_ref[...], (r, k, v, w))
    y_ref[0, 0] = ys.astype(y_ref.dtype)
    s_ref[...] = s_last

    @pl.when(si == ns - 1)
    def _fin():
        sn_ref[0, 0] = s_last


def rwkv6_scan_bhsn(r, k, v, w, u, s0, *, chunk: int = 128,
                    interpret: bool = False):
    """r,k,v,w: (B, H, S, N); u: (H, N); s0: (B, H, N, N) fp32.

    Returns (y (B,H,S,N) r.dtype, s_final (B,H,N,N) fp32). S % chunk == 0.
    """
    b, h, s, n = r.shape
    ns = s // chunk
    kern = functools.partial(_kernel, ns=ns)
    grid = (b, h, ns)
    spec_seq = pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, si: (b_, h_, si, 0))
    y, sn = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            spec_seq, spec_seq, spec_seq, spec_seq,
            pl.BlockSpec((1, n), lambda b_, h_, si: (h_, 0)),
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, si: (b_, h_, 0, 0)),
        ],
        out_specs=[
            spec_seq,
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, si: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, n), r.dtype),
            jax.ShapeDtypeStruct((b, h, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sn
