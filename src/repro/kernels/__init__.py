"""Pallas TPU kernels for the workload hot spots (DESIGN.md §6).

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), with jit'd
wrappers in ops.py and pure-jnp oracles in ref.py.  On CPU they run in
interpret mode; on TPU they compile to Mosaic.
"""
