"""RG-LRU linear-recurrence Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t   (elementwise over the lru_width channels)

TPU adaptation (DESIGN.md §6): GPU implementations use warp-level scans; on
TPU we tile channels across the 128-wide lanes (grid dim 1) and stream the
sequence through VMEM in (bs x br) tiles (grid dim 2, sequential), carrying
the (br,) state in VMEM scratch across tiles.  Inside a tile the recurrence
runs as a register-resident ``lax.scan`` over bs steps — each step is one
fused multiply-add over the lane dimension, which is exactly what the VPU
wants; HBM traffic is the roofline minimum (each a/b element read once,
each h written once).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, y_ref, hn_ref, h_ref, *, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)        # (bs, br)
    b = b_ref[0].astype(jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    h_last, ys = jax.lax.scan(step, h_ref[...], (a, b))
    y_ref[0] = ys.astype(y_ref.dtype)
    h_ref[...] = h_last

    @pl.when(si == ns - 1)
    def _fin():
        hn_ref[0] = h_last


def rglru_scan_bsr(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                   bs: int = 256, br: int = 128, out_dtype=None,
                   interpret: bool = False):
    """a, b: (B, S, R) fp32 coefficients; h0: (B, R) fp32.

    Returns (h_seq (B,S,R) out_dtype, h_last (B,R) fp32).
    S % bs == 0 and R % br == 0 are required (ops.py pads).
    """
    bsz, s, r = a.shape
    ns, nr = s // bs, r // br
    out_dtype = out_dtype or a.dtype
    kern = functools.partial(_kernel, ns=ns)
    grid = (bsz, nr, ns)                     # sequence dim last => sequential
    y, hn = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, br), lambda b_, ri, si: (b_, si, ri)),
            pl.BlockSpec((1, bs, br), lambda b_, ri, si: (b_, si, ri)),
            pl.BlockSpec((1, br), lambda b_, ri, si: (b_, ri)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, br), lambda b_, ri, si: (b_, si, ri)),
            pl.BlockSpec((1, br), lambda b_, ri, si: (b_, ri)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, r), out_dtype),
            jax.ShapeDtypeStruct((bsz, r), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((br,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, hn
