"""Flash attention Pallas TPU kernel (causal / sliding-window / GQA / softcap).

TPU adaptation (DESIGN.md §6): the GPU flash-attention algorithm is re-tiled
for the TPU memory hierarchy — (bq x d) query tiles and (bk x d) KV tiles are
staged HBM->VMEM by BlockSpecs; the (bq x bk) score tile hits the MXU; the
online-softmax running state (m, l, acc) lives in VMEM scratch that persists
across the sequential kv grid dimension.  GQA is expressed in the KV index
map (q-head h reads kv-head h // group), so no KV duplication ever reaches
VMEM.  Fully-masked kv tiles are skipped with ``pl.when``.

Layout: q (B, Hq, S, D);  k, v (B, Hkv, T, D);  out (B, Hq, S, D).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], nk: int, bq: int, bk: int,
            q_len: int, k_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)
    k_pos = ki * bk + jax.lax.iota(jnp.int32, bk)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # static skip is impossible on a sequential TPU grid; predicate instead
    block_live = ki >= 0
    if causal:
        block_live &= (ki * bk) <= (qi * bq + bq - 1)
    if window is not None:
        block_live &= (ki * bk + bk) > (qi * bq - window)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        ok = (k_pos[None, :] < k_len) & (q_pos[:, None] < q_len)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(ok, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
                         l_ref, acc_ref, *, scale: float,
                         softcap: Optional[float], bs: int, nblk: int):
    """One (slot, head-group, kv-block) step of decode-time paged attention.

    One body serves both grids (the per-head grid is exactly the g=1 shape
    of the fused one).  Fused flash-decoding grid (B, Hkv, M): all
    ``g = Hq/Hkv`` query heads of a GQA group are computed as one (g, d)
    tile against each KV block — every block staged HBM->VMEM exactly once
    per group (g x less KV traffic) and the score matmul is a real
    (g, d) x (d, bs) MXU tile rather than g separate matvecs.  Per-head
    A/B grid (B, Hq, M): the same body with g=1 query tiles, re-staging
    each block once per query head.

    The block table and context lengths arrive as scalar prefetch so the
    KV BlockSpec index map can chase ``tbl_ref`` — only the blocks a slot
    actually owns are ever staged into VMEM; there is no materialized
    (B, M*bs, ...) gather.  Online-softmax state ((g,)/(g, d) m, l, acc)
    persists in VMEM scratch across the sequential block grid dimension.
    """
    del tbl_ref                                   # consumed by the index maps
    b = pl.program_id(0)
    j = pl.program_id(2)
    ctx = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j * bs < ctx)                        # block holds written slots
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (g, d)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, d)
        v = v_ref[0, :, 0].astype(jnp.float32)               # (bs, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bs + jax.lax.iota(jnp.int32, bs)
        s = jnp.where((k_pos < ctx)[None, :], s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nblk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_chunk_kernel(tbl_ref, p0_ref, nl_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, scale: float,
                        softcap: Optional[float], bs: int, g: int, c: int,
                        nblk: int):
    """One (slot, head-group, kv-block) step of chunked-prefill attention.

    The chunked-prefill sibling of :func:`_paged_decode_kernel`: instead of
    one query token per slot, each grid step owns a whole C-token chunk of
    the slot's uncached suffix — all ``g = Hq/Hkv`` query heads of the GQA
    group for all C chunk positions flattened into one (C*g, d) query tile,
    so each KV block is still staged HBM->VMEM exactly once per group and
    the score matmul is a real (C*g, d) x (d, bs) MXU tile.

    Query row ``r`` is chunk position ``r // g`` at absolute position
    ``p0 + r // g``; causal masking admits key position ``k_pos`` when
    ``k_pos <= p0 + r // g``, which covers both the previously resident
    prefix blocks and intra-chunk causality in one predicate.  Rows past
    the chunk's live length (``r // g >= nl``) are forced to zero in the
    finalize step, matching the ref oracle's post-softmax re-mask.  Blocks
    entirely beyond the chunk's reach are predicated off with ``pl.when``;
    they are exact no-ops for live rows because block j=0 always computes
    and column 0 is always unmasked (m is finite before any skipped block).
    """
    del tbl_ref                                   # consumed by the index maps
    b = pl.program_id(0)
    j = pl.program_id(2)
    p0 = p0_ref[b]
    nl = nl_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    row = jax.lax.iota(jnp.int32, c * g)
    q_chunk = row // g                            # chunk position per q row

    @pl.when((nl > 0) & (j * bs < p0 + nl))       # block reachable by chunk
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (c*g, d)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, d)
        v = v_ref[0, :, 0].astype(jnp.float32)               # (bs, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bs + jax.lax.iota(jnp.int32, bs)
        ok = (q_chunk < nl)[:, None] & \
            (k_pos[None, :] <= (p0 + q_chunk)[:, None])
        s = jnp.where(ok, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nblk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        live = (q_chunk < nl).astype(jnp.float32)
        o_ref[0, 0] = ((acc_ref[...] / l[:, None])
                       * live[:, None]).astype(o_ref.dtype)


def paged_prefill_bhsd(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       block_tables: jax.Array, pos0: jax.Array,
                       n_live: jax.Array, *,
                       softcap: Optional[float] = None,
                       interpret: bool = False) -> jax.Array:
    """Chunked-prefill paged attention over a block-table KV pool.

    q: (B, Hq, C, D) — one C-token suffix chunk per slot (the chunk's KV
    must already be scattered into the pool);
    k_pool, v_pool: (N, bs, Hkv, D) — the shared physical block pool;
    block_tables: (B, M) int32 — per-slot physical block ids, logical order;
    pos0: (B,) int32 — absolute position of each chunk's first token;
    n_live: (B,) int32 — live tokens in the chunk (0..C; 0 = dead row).
    Returns (B, Hq, C, D); rows at chunk positions >= n_live are exact 0.

    Always the GQA-fused grid (B, Hkv, M): q regrouped so one grid step owns
    a whole group's (C*g, d) tile — the chunked generalization of the C=1
    flash-decoding grid, sharing its scalar-prefetch block-table gather.

    ``n_live`` is per-row, NOT per-grid: two slots in the same dispatch may
    score different live lengths (slot a: 8 suffix tokens; slot b: 3).
    Speculative verification (``ops.paged_verify``, ADR-008) leans on
    exactly this — each slot's window is its current token plus a
    *variable* number of draft proposals ``k_i``, so ``n_live = k_i + 1``
    varies per row while the kernel call, grid, and tile shapes stay
    fixed at the padded C.  Dead query rows cost only masked lanes of the
    same MXU tile, never an extra kernel call or KV fetch.
    """
    b, hq, c, d = q.shape
    _, bs, hkv, _ = k_pool.shape
    m = block_tables.shape[1]
    g = hq // hkv
    # (B, Hq, C, D) -> (B, Hkv, C*g, D): row r = chunk pos r // g, head r % g
    qg = q.reshape(b, hkv, g, c, d).swapaxes(2, 3).reshape(b, hkv, c * g, d)

    def kv_map(b_, h, j, tbl, p0, nl):
        return (tbl[b_, j], 0, h, 0)

    kern = functools.partial(_paged_chunk_kernel, scale=d ** -0.5,
                             softcap=softcap, bs=bs, g=g, c=c, nblk=m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, m),
        in_specs=[
            pl.BlockSpec((1, 1, c * g, d), lambda b_, h, j, tbl, p0, nl:
                         (b_, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), kv_map),
            pl.BlockSpec((1, bs, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, c * g, d), lambda b_, h, j, tbl, p0, nl:
                               (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c * g,), jnp.float32),
            pltpu.VMEM((c * g,), jnp.float32),
            pltpu.VMEM((c * g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, c * g, d), q.dtype),
        interpret=interpret,
    )(block_tables, pos0, n_live, qg, k_pool, v_pool)
    return out.reshape(b, hkv, c, g, d).swapaxes(2, 3).reshape(b, hq, c, d)


def paged_kv_fetches(b: int, hq: int, hkv: int, m: int, *,
                     fused: bool = True) -> int:
    """KV blocks staged HBM->VMEM per decode step, per pool tensor.

    Exactly the paged grid volume: the fused kernel walks (B, Hkv, M) and
    fetches each (slot, block) once per *group*; the per-head kernel walks
    (B, Hq, M) and re-stages every block g = Hq/Hkv times.  The benchmark
    (benchmarks/decode_micro.py) reports this, so it must stay in lockstep
    with the grids below.
    """
    return b * (hkv if fused else hq) * m


def paged_attention_bhsd(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                         block_tables: jax.Array, context_lens: jax.Array, *,
                         softcap: Optional[float] = None, fused: bool = True,
                         interpret: bool = False) -> jax.Array:
    """Decode-time paged attention over a block-table KV pool.

    q: (B, Hq, 1, D) — one query token per slot;
    k_pool, v_pool: (N, bs, Hkv, D) — the shared physical block pool;
    block_tables: (B, M) int32 — per-slot physical block ids, logical order;
    context_lens: (B,) int32 — tokens valid per slot.  Returns (B, Hq, 1, D).

    ``fused=True`` (default) runs the flash-decoding grid (B, Hkv, M): all
    g = Hq/Hkv query heads of a GQA group computed per KV block fetch.
    ``fused=False`` keeps the original per-query-head grid (B, Hq, M) for
    A/B measurement (see benchmarks/decode_micro.py).
    """
    b, hq, _, d = q.shape
    _, bs, hkv, _ = k_pool.shape
    m = block_tables.shape[1]
    g = hq // hkv
    # one kernel body, two grids: fused walks KV heads with (g, d) query
    # tiles (q regrouped (B, Hq, 1, D) -> (B, Hkv, g, D) so one grid step
    # owns a whole GQA group); per-head walks query heads with g=1 tiles
    gq = g if fused else 1                        # query rows per grid step
    hg = hkv if fused else hq                     # head grid dimension
    qg = q[:, :, 0, :].reshape(b, hg, gq, d)
    if fused:
        def kv_map(b_, h, j, tbl, cl):
            return (tbl[b_, j], 0, h, 0)
    else:
        def kv_map(b_, h, j, tbl, cl):
            return (tbl[b_, j], 0, h // g, 0)
    kern = functools.partial(_paged_decode_kernel, scale=d ** -0.5,
                             softcap=softcap, bs=bs, nblk=m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hg, m),
        in_specs=[
            pl.BlockSpec((1, 1, gq, d), lambda b_, h, j, tbl, cl:
                         (b_, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), kv_map),
            pl.BlockSpec((1, bs, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, gq, d), lambda b_, h, j, tbl, cl:
                               (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gq,), jnp.float32),
            pltpu.VMEM((gq,), jnp.float32),
            pltpu.VMEM((gq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hg, gq, d), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, qg, k_pool, v_pool)
    return out.reshape(b, hq, 1, d)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: Optional[int] = None,
                         softcap: Optional[float] = None, bq: int = 128,
                         bk: int = 128, q_len: Optional[int] = None,
                         k_len: Optional[int] = None,
                         interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D). S, T must divide bq, bk."""
    b, hq, s, d = q.shape
    _, hkv, t, _ = k.shape
    g = hq // hkv
    nq, nk = s // bq, t // bk
    q_len = s if q_len is None else q_len
    k_len = t if k_len is None else k_len
    kern = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal, window=window,
        softcap=softcap, nk=nk, bq=bq, bk=bk, q_len=q_len, k_len=k_len)
    return pl.pallas_call(
        kern,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
