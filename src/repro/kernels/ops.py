"""jit'd public wrappers for the Pallas kernels.

Handles layout (models use (B,S,H,D); kernels want (B,H,S,D)), padding to
block multiples, and the interpret-mode switch: on CPU (this container) the
kernels execute via ``interpret=True``; on TPU backends they compile to
Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import compression as _comp
from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _rw


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None, bq: int = 128,
                    bk: int = 128, interpret: Optional[bool] = None):
    """Model-layout wrapper. q: (B,S,Hq,D); k,v: (B,T,Hkv,D) -> (B,S,Hq,D)."""
    interpret = _interpret_default() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    bq = min(bq, max(8, qt.shape[2]))
    bk = min(bk, max(8, kt.shape[2]))
    qt, s0 = _pad_to(qt, 2, bq)
    kt, t0 = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)
    out = _fa.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   softcap=softcap, bq=bq, bk=bk, q_len=s0,
                                   k_len=t0, interpret=interpret)
    return jnp.swapaxes(out[:, :, :s0], 1, 2)


@functools.partial(jax.jit, static_argnames=("softcap", "fused", "interpret"))
def paged_attention(q, k_pool, v_pool, block_tables, context_lens, *,
                    softcap: Optional[float] = None, fused: bool = True,
                    interpret: Optional[bool] = None):
    """Decode-time paged attention, model layout.

    q: (B, 1, Hq, D) — the current token's query per slot;
    k_pool, v_pool: (N, bs, Hkv, D) physical KV block pool;
    block_tables: (B, M) int32; context_lens: (B,) int32.
    Returns (B, 1, Hq, D).  The kernel gathers KV blocks through the block
    table with scalar prefetch, so slots scattered anywhere in the pool cost
    the same as a contiguous cache.

    ``fused=True`` (default) is the flash-decoding grid: each KV block is
    staged once per GQA *group* and all g = Hq/Hkv query heads of the group
    hit the MXU as one (g, d) tile.  ``fused=False`` keeps the per-query-
    head grid for A/B measurement (benchmarks/decode_micro.py).
    """
    interpret = _interpret_default() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)                   # (B, Hq, 1, D)
    out = _fa.paged_attention_bhsd(
        qt, k_pool, v_pool, block_tables.astype(jnp.int32),
        context_lens.astype(jnp.int32), softcap=softcap, fused=fused,
        interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_prefill(q, k_pool, v_pool, block_tables, pos0, n_live, *,
                  softcap: Optional[float] = None,
                  interpret: Optional[bool] = None):
    """Chunked-prefill paged attention, model layout.

    q: (B, C, Hq, D) — one C-token suffix chunk per slot (the chunk's KV
    must already be scattered into the pool);
    k_pool, v_pool: (N, bs, Hkv, D) physical KV block pool;
    block_tables: (B, M) int32; pos0, n_live: (B,) int32 (chunk start
    position / live token count per slot).  Returns (B, C, Hq, D) with
    rows at chunk positions >= n_live exactly zero.

    The chunked generalization of :func:`paged_attention`: one dispatch
    covers C suffix tokens per slot instead of one, attending over all
    previously resident blocks plus the chunk itself (causal), via the
    same GQA-fused scalar-prefetch block-table gather.
    """
    interpret = _interpret_default() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)                   # (B, Hq, C, D)
    out = _fa.paged_prefill_bhsd(
        qt, k_pool, v_pool, block_tables.astype(jnp.int32),
        pos0.astype(jnp.int32), n_live.astype(jnp.int32),
        softcap=softcap, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_verify(q, k_pool, v_pool, block_tables, pos0, n_live, *,
                 softcap: Optional[float] = None,
                 interpret: Optional[bool] = None):
    """Speculative-verification paged attention, model layout.

    q: (B, C, Hq, D) — per slot, the queries of the current token plus its
    ``k_i`` draft proposals (C = K+1 padded; the window's KV must already
    be scattered into the pool); block_tables: (B, M) int32; pos0 (B,)
    the slot cursor; n_live (B,) = ``k_i + 1`` live window tokens (0 =
    dead row).  Returns (B, C, Hq, D) with rows >= n_live exactly zero.

    This is the per-row *variable-K* generalization the verification path
    needs (docs/architecture.md ADR-008), and it is exactly the
    ``paged_prefill`` contract: the GQA-fused chunk kernel already masks
    per row with ``q_chunk < n_live[b]`` and causally with
    ``k_pos <= pos0[b] + q_chunk``, so every slot scores all K+1
    positions in ONE kernel call per layer — one (C*g, d) MXU tile per
    (slot, group, kv-block) — regardless of how many proposals each slot
    brought.  Stale KV from previously rejected tokens sits at positions
    beyond ``pos0 + n_live - 1`` and is causally masked off; positions
    below that were overwritten by this window's scatter before the call
    (write-then-attend), which is the whole containment argument for
    lossless speculation.  Kept as a named entry point so the verify
    path's kernel contract is explicit and can diverge (e.g. a fused
    accept reduction) without touching the prefill path.
    """
    return paged_prefill(q, k_pool, v_pool, block_tables, pos0, n_live,
                         softcap=softcap, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("axis",))
def copy_blocks(leaf, src, dst, *, axis: int = 0):
    """Device-side KV block copy: ``leaf[dst] = leaf[src]`` along ``axis``.

    The copy-on-write primitive of the prefix cache (docs/architecture.md
    ADR-003): when a new prompt diverges partway into a cached block, the
    allocator maps a *fresh* block for the slot and the serving layer copies
    the cached block's contents into it on device — the slot then overwrites
    the divergent tail in place while the shared source stays immutable.

    src, dst: (C,) int32 physical block ids.  Pairs are independent (every
    dst is freshly allocated, so no pair's dst is another pair's src);
    (0, 0) pairs are harmless no-ops, which is what lets callers pad the
    pair list to a fixed bucket size.  Runs as one fused gather+scatter —
    one dispatch per pool leaf regardless of the number of pairs.
    """
    moved = jnp.moveaxis(leaf, axis, 0)
    moved = moved.at[dst].set(moved[src])
    return jnp.moveaxis(moved, 0, axis)


@jax.jit
def quantize_kv_blocks(blocks):
    """Device-side per-(block, head) int8 quantization of KV blocks.

    ``blocks``: (n, bs, Hkv, D) — KV blocks gathered along the pool's
    block axis (leaves without a head axis fall back to per-block
    scales).  Returns ``(q int8, scales float32 keepdims)``.  The wire
    half of compressed KV transfer (docs/architecture.md ADR-009): a
    disaggregated prefill→decode handoff ships the int8 payload plus the
    scales over the inter-clone link instead of the full-width blocks,
    ~4x fewer modeled bytes at bf16 pools.
    """
    return _comp.quantize_kv_blocks(blocks)


@functools.partial(jax.jit, static_argnames=("dtype",))
def dequantize_kv_blocks(q, scales, *, dtype=jnp.bfloat16):
    """Device-side inverse of :func:`quantize_kv_blocks`.

    Runs on the receiving clone before the blocks are scattered into its
    pool; tokens decoded from dequantized KV may drift from the
    uncompressed path within the declared int8 tolerance.
    """
    return _comp.dequantize_kv_blocks(q, scales, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("bs", "br", "interpret"))
def rglru_scan(a, b, h0=None, *, bs: int = 256, br: int = 128,
               interpret: Optional[bool] = None):
    """a, b: (B,S,R) recurrence coefficients; h0: (B,R) or None.

    Returns (h_seq (B,S,R), h_last (B,R) fp32).
    """
    interpret = _interpret_default() if interpret is None else interpret
    bsz, s, r = a.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, r), jnp.float32)
    bs = min(bs, s)
    br = br if r % br == 0 else r
    a_p, s0 = _pad_to(a, 1, bs)
    b_p, _ = _pad_to(b, 1, bs)
    pad = a_p.shape[1] - s0
    if pad:
        # padded steps: a=1, b=0 -> state carries through unchanged
        a_p = a_p.at[:, s0:].set(1.0)
    y, hn = _rg.rglru_scan_bsr(a_p.astype(jnp.float32),
                               b_p.astype(jnp.float32),
                               h0.astype(jnp.float32), bs=bs, br=br,
                               out_dtype=a.dtype, interpret=interpret)
    return y[:, :s0], hn


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0=None, *, chunk: int = 128,
               interpret: Optional[bool] = None):
    """Model-layout wrapper. r,k,v,w: (B,S,H,N); u: (H,N); s0: (B,H,N,N).

    Returns (y (B,S,H,N), s_final (B,H,N,N) fp32).
    """
    interpret = _interpret_default() if interpret is None else interpret
    b, s, h, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, n, n), jnp.float32)
    chunk = min(chunk, s)
    rt, kt, vt, wt = (jnp.swapaxes(x, 1, 2) for x in (r, k, v, w))
    rt, len0 = _pad_to(rt, 2, chunk)
    kt, _ = _pad_to(kt, 2, chunk)
    vt, _ = _pad_to(vt, 2, chunk)
    # padded steps: w=1 (state unchanged), k=0 (no injection)
    pad = rt.shape[2] - len0
    if pad:
        wt = jnp.concatenate(
            [wt, jnp.ones((b, h, pad, n), wt.dtype)], axis=2)
    y, sn = _rw.rwkv6_scan_bhsn(rt, kt, vt, wt, u, s0, chunk=chunk,
                                interpret=interpret)
    return jnp.swapaxes(y[:, :, :len0], 1, 2), sn
