"""Step functions: train / prefill / decode, plus their sharding trees.

These are the units the multi-pod dry-run lowers and the ThinkAir serving /
training layers execute.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import model
from repro.models.context import RunContext
from repro.optim import adamw


def make_context(mesh: Optional[Mesh], **kw) -> RunContext:
    if mesh is None:
        return RunContext(mesh=None, **kw)
    return RunContext(mesh=mesh, dp_axes=shd.batch_axes(mesh), **kw)


# --------------------------------------------------------------------------- #
# Builders
# --------------------------------------------------------------------------- #
def build_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                     ctx: RunContext):
    k = max(1, ctx.microbatches)

    def loss_fn(params, batch):
        return model.forward(cfg, params, batch, ctx, "train")

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        if k == 1:
            (total, metrics), grads = grad_fn(state["params"], batch)
        else:
            # gradient accumulation: activation memory / k at equal FLOPs;
            # the per-microbatch grad reduce-scatter can overlap the next
            # microbatch's compute (latency-hiding scheduler)
            mb = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)
            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def body(carry, mb_i):
                gacc, tot, met = carry
                (total_i, metrics_i), g = grad_fn(state["params"], mb_i)
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gacc, g)
                met = jax.tree.map(lambda a, b: a + b / k, met, metrics_i)
                return (gacc, tot + total_i / k, met), None

            met0 = {"loss": jnp.zeros((), jnp.float32),
                    "aux": jnp.zeros((), jnp.float32)}
            (grads, total, metrics), _ = jax.lax.scan(
                body, (gacc0, jnp.zeros((), jnp.float32), met0), mb,
                unroll=k if ctx.scan_unroll else 1)
            grads = jax.tree.map(lambda g: g / k, grads)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, state["opt"],
                                               state["params"])
        metrics = dict(metrics)
        metrics.update(om)
        metrics["total"] = total
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, ctx: RunContext,
                       cache_capacity: int = 0):
    def prefill_step(params: Dict, batch: Dict):
        return model.forward(cfg, params, batch, ctx, "prefill",
                             cache_capacity=cache_capacity)

    return prefill_step


def build_decode_step(cfg: ModelConfig, ctx: RunContext):
    def decode_step(params: Dict, cache: Dict, tokens: jax.Array,
                    pos: jax.Array):
        return model.decode_step(cfg, params, cache, tokens, pos, ctx)

    return decode_step


# --------------------------------------------------------------------------- #
# Abstract state + shardings
# --------------------------------------------------------------------------- #
def abstract_state(cfg: ModelConfig):
    params = model.init_abstract(cfg)
    opt = jax.eval_shape(adamw.init, params)
    return {"params": params, "opt": opt}


def state_logical_axes(cfg: ModelConfig):
    axes = model.param_logical_axes(cfg)
    return {"params": axes,
            "opt": {"mu": axes, "nu": axes, "step": ()}}


def state_shardings(cfg: ModelConfig, mesh: Mesh, profile: str = "tp"):
    return shd.tree_shardings(abstract_state(cfg), state_logical_axes(cfg),
                              mesh, shd.rules_for(profile))


def param_shardings(cfg: ModelConfig, mesh: Mesh, profile: str = "tp"):
    return shd.tree_shardings(model.init_abstract(cfg),
                              model.param_logical_axes(cfg), mesh,
                              shd.rules_for(profile))


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, capacity: int,
                    profile: str = "tp"):
    ab = model.abstract_cache(cfg, batch, capacity)
    axes = model.cache_logical_axes(cfg)
    return shd.tree_shardings(ab, axes, mesh, shd.rules_for(profile))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
