"""ShapeDtypeStruct stand-ins for every model input (dry-run deliverable).

``input_specs(cfg, shape)`` returns the exact abstract inputs the step fn for
that (arch x shape) cell is lowered with — weak-type-correct, shardable, and
never allocated.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import model


def _drop_targets(batch_abs: Dict) -> Dict:
    return {k: v for k, v in batch_abs.items()
            if k not in ("targets", "loss_mask")}


def cache_capacity(cfg: ModelConfig, seq_len: int) -> int:
    return min(cfg.window, seq_len) if cfg.window else seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Abstract inputs for the cell's step function.

    train   -> {"batch": {...}}
    prefill -> {"batch": {...}} (no targets)
    decode  -> {"cache": ..., "tokens": (B,1), "pos": scalar}
    """
    pipe = Pipeline(cfg, DataConfig(shape.global_batch, shape.seq_len))
    batch_abs = pipe.abstract_batch()
    if shape.kind == "train":
        return {"batch": batch_abs}
    if shape.kind == "prefill":
        return {"batch": _drop_targets(batch_abs)}
    cap = cache_capacity(cfg, shape.seq_len)
    return {
        "cache": model.abstract_cache(cfg, shape.global_batch, cap),
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
