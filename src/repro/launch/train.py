"""Training driver with ThinkAir fleet integration.

Runnable at laptop scale (``--reduced``) and lowerable at production scale.
Fleet features (DESIGN.md §8):
 - checkpoint/restart (atomic, async, step-versioned);
 - elastic data-parallel scaling through the ThinkAir clone pool (resizes
   between steps; provisioning charged like the paper's VM resumes);
 - fault injection -> restore-from-checkpoint restart path;
 - optional manual-collective DP with int8+error-feedback gradient
   compression (shard_map path, used when the mesh has >1 data shard).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config, reduced_config
from repro.core.clones import ClonePool
from repro.core.faults import FaultPlan
from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed import compression
from repro.launch import steps as S
from repro.models import model
from repro.optim import adamw
from repro.optim.adamw import OptConfig


def build_compressed_train_step(cfg, opt_cfg, ctx):
    """Manual-DP: per-shard grads, int8+EF all-reduce over 'data'."""
    from jax.sharding import PartitionSpec as P

    def step_fn(state: Dict, batch: Dict):
        def local_step(params, opt, ef, local_batch):
            def loss_fn(p):
                local_ctx = dataclasses.replace(ctx, mesh=None)
                return model.forward(cfg, p, local_batch, local_ctx, "train")

            (total, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            grads, ef = compression.tree_compressed_pmean(grads, ef, "data")
            new_params, new_opt, om = adamw.update(opt_cfg, grads, opt,
                                                   params)
            metrics = {**metrics, **om, "total": total}
            metrics = jax.tree.map(lambda x: jax.lax.pmean(x, "data"),
                                   metrics)
            return new_params, new_opt, ef, metrics

        from repro.distributed.sharding import shard_map_compat
        new_p, new_o, new_ef, metrics = shard_map_compat(
            local_step, mesh=ctx.mesh,
            in_specs=(P(), P(), P(), P("data")),
            out_specs=(P(), P(), P(), P()),
        )(state["params"], state["opt"], state["ef"], batch)
        return {"params": new_p, "opt": new_o, "ef": new_ef}, metrics

    return step_fn


@dataclasses.dataclass
class TrainReport:
    steps_done: int = 0
    restarts: int = 0
    resizes: int = 0
    provision_seconds: float = 0.0
    losses: list = dataclasses.field(default_factory=list)


class FleetTrainer:
    """Elastic, fault-tolerant training loop driven by the ThinkAir pool."""

    def __init__(self, cfg, *, steps_total: int, data_cfg: DataConfig,
                 opt_cfg: OptConfig = OptConfig(), ckpt_dir: str = None,
                 ckpt_every: int = 20, fault_plan: Optional[FaultPlan] = None,
                 grad_compression: bool = False, mesh=None,
                 elastic_schedule: Optional[dict] = None):
        self.cfg = cfg
        self.steps_total = steps_total
        self.opt_cfg = opt_cfg
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.pipe = Pipeline(cfg, data_cfg)
        self.faults = fault_plan or FaultPlan()
        self.pool = ClonePool(link_name="dcn", tpu=True)
        self.elastic_schedule = elastic_schedule or {}
        self.report = TrainReport()
        self.mesh = mesh
        self.ctx = S.make_context(mesh)
        if grad_compression and mesh is not None \
                and mesh.shape.get("data", 1) > 1:
            self._build = lambda: build_compressed_train_step(
                cfg, opt_cfg, self.ctx)
            self._compressed = True
        else:
            self._build = lambda: S.build_train_step(cfg, opt_cfg, self.ctx)
            self._compressed = False
        self.step_fn = jax.jit(self._build())

    def init_state(self, seed: int = 0) -> Dict:
        params = model.init(self.cfg, jax.random.PRNGKey(seed))
        state = {"params": params, "opt": adamw.init(params)}
        if self._compressed:
            state["ef"] = compression.init_error_feedback(params)
        return state

    def run(self, state: Optional[Dict] = None) -> Dict:
        start = 0
        if state is None:
            state = self.init_state()
            if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
                start, state = ckpt.restore(self.ckpt_dir, state)
                self.report.restarts += 1
        i = start
        while i < self.steps_total:
            if i in self.elastic_schedule:
                # elastic resize: provision clones; cost accounted like the
                # paper's VM resume/boot
                n = self.elastic_schedule[i]
                _, cost = self.pool.acquire("main", n=n)
                self.report.provision_seconds += cost
                self.report.resizes += 1
            batch = self.pipe.batch(i)
            if self.faults.check():
                # node failure mid-step: restart from latest checkpoint
                self.report.restarts += 1
                if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) \
                        is not None:
                    i, state = ckpt.restore(self.ckpt_dir, state)
                continue
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            self.report.losses.append(loss)
            i += 1
            self.report.steps_done += 1
            if self.ckpt_dir and i % self.ckpt_every == 0:
                ckpt.save(self.ckpt_dir, i, state)
        if self.ckpt_dir:
            ckpt.save(self.ckpt_dir, i, state)
        return state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    trainer = FleetTrainer(
        cfg, steps_total=args.steps,
        data_cfg=DataConfig(args.batch, args.seq),
        ckpt_dir=args.ckpt_dir)
    t0 = time.time()
    state = trainer.init_state()
    for i in range(args.steps):
        batch = trainer.pipe.batch(i)
        state, metrics = trainer.step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.time() - t0:.1f}s)")
    print("done")


if __name__ == "__main__":
    main()
