"""Roofline analysis from dry-run artifacts (deliverable (g)).

Three terms per (arch x shape x mesh), in seconds-per-step:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197e12 bf16)
    memory     = HLO_bytes_per_device / HBM_bw               (819e9 B/s)
    collective = collective_bytes_per_device / link_bw       (50e9 B/s ICI)

HLO FLOPs/bytes come from the trip-count-corrected dry-run numbers (XLA's
cost_analysis counts while-loop bodies once; dryrun.py recovers per-group
cost from k=1/k=2 unrolled lowerings).  MODEL_FLOPS = 6*N*D (train) or
2*N_active*D (serve) gives the usefulness ratio — how much of compiled
compute is algorithmically necessary.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def model_flops(rec: Dict) -> float:
    """Algorithmic FLOPs for the whole step (global)."""
    from repro.configs import get_config, get_shape
    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    n_active = rec.get("n_active_params")
    kind = rec["kind"]
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # decode: 1 token/seq


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    corr = rec.get("corrected", {})
    flops = corr.get("flops") or rec["cost_reported"]["flops"]
    nbytes = corr.get("bytes_accessed") or \
        rec["cost_reported"]["bytes_accessed"]
    coll = corr.get("collective_bytes")
    if coll is None:
        coll = rec["collectives_reported"]["total"]
    n_dev = rec["n_devices"]
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": nbytes / HBM_BW,
        "collective_s": coll / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful = (mf / n_dev) / max(flops, 1.0)
    bound_s = max(terms.values())
    # roofline fraction: useful work per device vs what the bound allows
    achievable_mfu = (mf / n_dev / bound_s) / PEAK_FLOPS if bound_s else 0.0
    return {
        "cell": f"{rec['arch']}/{rec['shape']}/{rec['mesh']}",
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops_global": mf,
        "hlo_flops_dev": flops,
        "usefulness": useful,
        "roofline_mfu": achievable_mfu,
        "peak_gib": rec["memory"]["peak_bytes"] / 2 ** 30,
        "fits_hbm": rec["fits_hbm"],
        "compile_s": rec.get("compile_seconds"),
    }


_MOVE_NOTES = {
    "compute": ("compute-bound: raise MFU via larger per-core tiles / fewer "
                "redundant FLOPs (usefulness below 1 indicates remat or "
                "replicated compute to eliminate)"),
    "memory": ("HBM-bound: fuse/flash the bandwidth hot spot, cut remat "
               "traffic, or re-tile so the working set stays in VMEM"),
    "collective": ("ICI-bound: reshard to reduce gathered bytes, overlap "
                   "collectives with compute, or compress the payload"),
}


def load_records(results_dir: str = RESULTS_DIR, tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) >= 4:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(results_dir: str = RESULTS_DIR, tag: str = "",
          mesh: Optional[str] = None) -> str:
    rows = []
    skips = []
    for rec in load_records(results_dir, tag):
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skip":
            skips.append(f"{rec['arch']}/{rec['shape']}/{rec['mesh']}: "
                         f"{rec['reason']}")
            continue
        a = analyze(rec)
        if a:
            rows.append(a)
    rows.sort(key=lambda r: r["cell"])
    hdr = (f"{'cell':50s} {'compute':>10s} {'memory':>10s} {'collect':>10s} "
           f"{'dom':>8s} {'useful':>7s} {'rMFU':>6s} {'GiB/dev':>8s} fits")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['cell']:50s} {r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {r['dominant']:>8s} "
            f"{r['usefulness']:7.3f} {r['roofline_mfu']:6.3f} "
            f"{r['peak_gib']:8.2f} {'y' if r['fits_hbm'] else 'N'}")
    if skips:
        lines.append("")
        lines.extend(f"[skip] {s}" for s in skips)
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=RESULTS_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    print(table(args.dir, args.tag, args.mesh))


if __name__ == "__main__":
    main()
