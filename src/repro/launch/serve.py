"""Serving stack: ThinkAir's Client Handler for LM inference.

Two layers share one model binding (``LMBackend``):

``ServingEngine`` — the batch-at-a-time path (seed behaviour).  Each request
batch is a remoteable method invocation: the ExecutionController decides
placement (local small venue vs cloud clones) per batch from profiled
history; long-context requests whose KV-cache working set exceeds the
default clone's memory are escalated to a bigger clone type (the paper's
OutOfMemoryError path); prefill for large batches can be split across k
clones (the paper's parallelization path).

``ClientHandler`` — the event-driven continuous-batching server (paper
§5.2-§5.3, the tentpole of the Client Handler refactor).  Requests arrive
on a shared :class:`~repro.core.clock.VirtualClock`, pass admission control
(:class:`~repro.core.scheduler.AdmissionQueue`), and are formed into
*cohorts* of up to ``max_batch`` requests.  Each cohort's prefill and every
decode step is a non-blocking :class:`~repro.core.dispatch.Dispatcher` task
on one clone, so cohorts on different clones genuinely overlap on the
timeline.  Requests **leave** their cohort at decode-step granularity the
moment they hit their token budget (the cohort's KV cache shrinks in
place), and new arrivals **enter** service at the next step boundary on any
free clone — they never wait for a whole batch to drain.  A queue-depth
driven :class:`~repro.core.scheduler.QueueAutoscaler` provisions and
TTL-pauses secondaries through the ClonePool lifecycle, which makes the
paper's elasticity claim measurable as p50/p99 latency and tokens/s under
Poisson offered load (see ``benchmarks/serving_load.py``).

KV cache modes: the default ``kv="paged"`` path batches at *slot*
granularity — each clone runs a :class:`_SlotEngine` whose requests each
own a per-slot decode cursor and a row of a block table over a fixed
:class:`KVBlockPool`; a late arrival is prefilled into any free slot of an
in-flight engine at the next decode step (no step-boundary fusion, no
``cache_take`` re-gather on retire).  ``kv="contiguous"`` keeps the PR-1
cohort path — one shared cursor, fusion only at the same step boundary —
as the measurable baseline (see ``benchmarks/serving_load.py`` and
``docs/architecture.md``).  Weights are resident on the clones (serving
fleet), so per-request network cost is prompt/token traffic only — unlike
the offload path, which ships the method's whole state.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import (ClonePool, ExecutionController, Policy,
                        RemoteableMethod)
from repro.core.clock import VirtualClock
from repro.core.dispatch import Dispatcher
from repro.core.scheduler import (AdmissionQueue, QueueAutoscaler,
                                  ServeCompletion, ServeRequest, SlotLedger,
                                  poisson_arrivals)
from repro.core.venues import Venue, pytree_bytes, transfer_time
from repro.launch import steps as S
from repro.models import model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prefill_venue: str
    decode_venue: str
    latency_s: float
    escalations: int


class LMBackend:
    """Model binding: params + jitted prefill/decode + cache batch surgery."""

    def __init__(self, cfg, capacity: int = 256):
        self.cfg = cfg
        self.capacity = capacity
        self.ctx = S.make_context(None,
                                  moe_capacity_factor=(
                                      cfg.n_experts / cfg.top_k
                                      if cfg.is_moe else 1.25))
        self.params = model.init(cfg, jax.random.PRNGKey(0))
        cap = capacity

        def prefill_fn(params, tokens):
            logits, cache = model.forward(cfg, params, {"tokens": tokens},
                                          self.ctx, "prefill",
                                          cache_capacity=cap)
            return jnp.argmax(logits, -1), cache

        def decode_fn(params, cache, tokens, pos):
            logits, cache = model.decode_step(cfg, params, cache, tokens,
                                              pos, self.ctx)
            return jnp.argmax(logits, -1), cache

        self.prefill = jax.jit(prefill_fn)
        self.decode = jax.jit(decode_fn)
        # locate each cache leaf's batch/capacity axes by diffing shapes
        self._batch_axis, self._cap_axis = model.cache_axes(cfg)
        self._paged_fns: Dict[tuple, tuple] = {}      # (bs, donate)
        self._paged_win_fns: Dict[tuple, object] = {}  # (bs, window, donate)

    def cache_mem_bytes(self, batch: int) -> int:
        return pytree_bytes(model.abstract_cache(self.cfg, batch,
                                                 self.capacity))

    def cache_take(self, cache, keep_idx) -> Dict:
        """Shrink a cohort cache to the surviving batch rows."""
        idx = jnp.asarray(np.asarray(keep_idx, np.int32))

        def take(leaf, ax):
            return leaf if ax is None else jnp.take(leaf, idx, axis=ax)

        return jax.tree.map(take, cache, self._batch_axis)

    # ---------------------------------------------------------------- paged
    def init_paged_pool(self, max_slots: int, num_blocks: int,
                        block_size: int):
        """Zero KV block pool + per-slot state rows (block 0 = trash)."""
        return model.init_paged_cache(self.cfg, max_slots, num_blocks,
                                      block_size)

    def paged_fns(self, block_size: int, window: int = 1,
                  donate: bool = False):
        """(prefill_into, decode_slots, decode_window) jitted fns.

        ``prefill_into(params, toks (J,P), pool, blk_ids (J,nb0), slots
        (J,))`` prefills J prompts in one batched call and scatters each
        row's KV into its pool blocks (and its recurrent state into its
        slot row), returning ``(first_tokens (J,), new_pool)``.  Joins
        landing at the same step boundary therefore cost one prefill, like
        a contiguous cohort.  Rows whose slot id is out of range (the
        power-of-two bucket padding) scatter nowhere: their state-row
        update is dropped and their KV lands in the trash block.

        ``decode_slots(params, pool, tok (S,1), pos (S,), tables (S,M))``
        runs one decode step for every slot at its own cursor, returning
        ``(next_tokens (S,), new_pool)``.  Inactive slots must carry
        ``pos=0`` and an all-zero table row so their writes land in the
        trash block.

        ``decode_window(params, pool, tok (S,1), pos (S,), steps_left (S,),
        tables (S,M))`` is the flash-decoding fast path: ``window`` greedy
        steps fused into one ``lax.scan`` dispatch (``model.decode_loop``),
        returning ``(tokens (S, window), new_pool)``.  Rows exhaust their
        ``steps_left`` mid-window and park further writes in the trash
        block until the host-side boundary.

        ``donate=True`` adds ``donate_argnums`` on the pool so each step
        updates the KV pool in place instead of deep-copying it.  A donated
        call *consumes* its pool argument — callers whose executor re-runs
        a closure (the default simulated Venue re-times cheap calls) must
        keep ``donate=False``; see docs/architecture.md ADR-002.
        """
        # prefill_into / decode_slots don't depend on the window: cache
        # them under (bs, donate) so handlers with different windows share
        # one compiled prefill graph; only decode_window is window-keyed
        base_key = (block_size, donate)
        win_key = (block_size, window, donate)
        if base_key in self._paged_fns and win_key in self._paged_win_fns:
            return self._paged_fns[base_key] + (self._paged_win_fns[win_key],)
        cfg, ctx = self.cfg, self.ctx
        b_ax, c_ax = self._batch_axis, self._cap_axis
        capacity = self.capacity

        def prefill_into(params, toks, pool, blk_ids, slots):
            j, nb0 = blk_ids.shape
            logits, pcache = model.forward(
                cfg, params, {"tokens": toks}, ctx, "prefill",
                cache_capacity=nb0 * block_size)
            flat_ids = blk_ids.reshape(-1)

            def scatter(pool_leaf, pre, bax, cax):
                if cax is None:                      # per-slot state rows
                    lp = jnp.moveaxis(pool_leaf, bax, 0)
                    rows = jnp.moveaxis(pre, bax, 0)
                    return jnp.moveaxis(lp.at[slots].set(rows, mode="drop"),
                                        0, bax)
                lp = jnp.moveaxis(pool_leaf, (bax, cax), (0, 1))
                pr = jnp.moveaxis(pre, (bax, cax), (0, 1))
                pr = pr.reshape((j * nb0, block_size) + pr.shape[2:])
                return jnp.moveaxis(lp.at[flat_ids].set(pr), (0, 1),
                                    (bax, cax))

            pool = jax.tree.map(scatter, pool, pcache, b_ax, c_ax)
            return jnp.argmax(logits, -1), pool

        def decode_slots(params, pool, tok, pos, tables):
            logits, pool = model.decode_step(
                cfg, params, pool, tok, pos, ctx, block_tables=tables,
                block_size=block_size)
            return jnp.argmax(logits, -1), pool

        def decode_window(params, pool, tok, pos, steps_left, tables):
            return model.decode_loop(
                cfg, params, pool, tok, pos, steps_left, ctx,
                block_tables=tables, block_size=block_size,
                num_steps=window, capacity=capacity)

        if base_key not in self._paged_fns:
            self._paged_fns[base_key] = (
                jax.jit(prefill_into, donate_argnums=(2,)),
                jax.jit(decode_slots, donate_argnums=(1,))) if donate else (
                jax.jit(prefill_into), jax.jit(decode_slots))
        self._paged_win_fns[win_key] = jax.jit(
            decode_window, donate_argnums=(1,) if donate else ())
        return self._paged_fns[base_key] + (self._paged_win_fns[win_key],)


class ServingEngine:
    """Batched prefill + decode with ThinkAir placement decisions."""

    def __init__(self, cfg, *, policy: Policy = Policy.EXEC_TIME,
                 link: str = "wifi-local", max_batch: int = 8,
                 capacity: int = 256, backend: Optional[LMBackend] = None):
        self.cfg = cfg
        self.max_batch = max_batch
        self.capacity = capacity
        self.backend = backend or LMBackend(cfg, capacity)
        self.params = self.backend.params
        self.ec = ExecutionController(policy=policy, link=link)
        self.ec.pool.provision("main", 8)       # paused secondaries (paper)
        backend_ = self.backend

        # KV working set drives escalation: bytes ~ cache size
        def prefill_mem(params, tokens):
            return backend_.cache_mem_bytes(tokens.shape[0])

        self.rm_prefill = RemoteableMethod(
            "serve_prefill", self.backend.prefill, jit=False,
            size_fn=lambda p, t: t.size,
            split_fn=self._split_prefill, merge_fn=self._merge_prefill,
            mem_fn=prefill_mem)
        self.rm_decode = RemoteableMethod(
            "serve_decode", self.backend.decode, jit=False,
            size_fn=lambda p, c, t, pos: t.shape[0])
        self.stats = {"requests": 0, "batches": 0, "offloaded": 0,
                      "escalations": 0}

    @staticmethod
    def _split_prefill(args, k):
        params, tokens = args
        tok_shards = np.array_split(np.asarray(tokens), k, axis=0)
        return [(params, jnp.asarray(t)) for t in tok_shards]

    @staticmethod
    def _merge_prefill(values):
        toks = jnp.concatenate([v[0] for v in values], axis=0)
        caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                              *[v[1] for v in values])
        return toks, caches

    def serve_batch(self, reqs: List[Request], *, n_clones: int = 1,
                    force: Optional[str] = None) -> List[Completion]:
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
        res_p = self.ec.execute(self.rm_prefill, self.params,
                                jnp.asarray(toks), n_clones=n_clones,
                                force=force)
        next_tok, cache = res_p.value
        out = [list() for _ in reqs]
        steps_needed = max(r.max_new_tokens for r in reqs)
        tok = next_tok[:, None]
        total_time = res_p.time_s
        decode_venue = "-"
        # per-batch aggregation over prefill AND every decode step
        offloaded = int(res_p.offloaded)
        escalations = res_p.escalations
        for step_i in range(steps_needed):
            for i in range(len(reqs)):
                out[i].append(int(tok[i, 0]))
            pos = jnp.int32(min(plen + step_i, self.capacity - 1))
            res_d = self.ec.execute(self.rm_decode, self.params, cache, tok,
                                    pos, force=force)
            tok, cache = res_d.value
            tok = tok[:, None]
            total_time += res_d.time_s
            decode_venue = res_d.venue
            offloaded += int(res_d.offloaded)
            escalations += res_d.escalations
        self.stats["requests"] += len(reqs)
        self.stats["batches"] += 1
        self.stats["offloaded"] += offloaded
        self.stats["escalations"] += escalations
        return [Completion(r.rid, out[i], res_p.venue, decode_venue,
                           total_time, escalations)
                for i, r in enumerate(reqs)]


# --------------------------------------------------------------------------- #
# Event-driven Client Handler (continuous batching + elastic clones)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _Cohort:
    """Requests admitted at one step boundary, decoding in lockstep.

    This is the *contiguous* (legacy, ``kv="contiguous"``) batching unit:
    one shared position cursor, so only requests admitted at the same step
    boundary fuse, and late arrivals wait for a clone to free up.  The paged
    path (:class:`_SlotEngine`) replaces it as the default.
    """

    reqs: List[ServeRequest]
    clone: object
    plen: int
    outs: List[List[int]] = dataclasses.field(default_factory=list)
    first_token_t: List[float] = dataclasses.field(default_factory=list)
    cache: object = None
    tok: object = None
    step: int = 0
    phase: str = "prefill"


class KVBlockPool:
    """Host-side paged-KV bookkeeping for one engine (one clone).

    Owns the device block pool plus the block table, per-slot decode
    cursors, and the free lists.  Block id 0 is the *trash block*: it is
    never allocated, every inactive slot's table points at it, so decode
    writes from idle rows land somewhere harmless.  Blocks are allocated
    lazily — ``ceil(prompt/len block_size)`` at admission, then one at a
    time as a slot's cursor crosses a block boundary — which is what makes
    KV memory track *written* tokens instead of worst-case capacity.
    """

    def __init__(self, backend, max_slots: int, block_size: int,
                 num_blocks: Optional[int] = None):
        self.backend = backend
        self.bs = block_size
        self.max_slots = max_slots
        self.capacity = backend.capacity
        self.max_blk = -(-backend.capacity // block_size)
        # default pool provisions worst case (+1 for the trash block), so
        # admission can never deadlock; benchmarks may size it tighter
        self.num_blocks = num_blocks or max_slots * self.max_blk + 1
        self.pool = backend.init_paged_pool(max_slots, self.num_blocks,
                                            block_size)
        self.tables = np.zeros((max_slots, self.max_blk), np.int32)
        self.pos = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self.n_blocks_of = np.zeros((max_slots,), np.int32)
        self.need = np.zeros((max_slots,), np.int32)
        self.committed = 0          # blocks promised to slots, unallocated
        # bumped on every host-side table mutation; _SlotEngine caches the
        # device copy of ``tables`` against it (re-upload only when dirty)
        self.tables_version = 0
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self._free_blocks = list(range(self.num_blocks - 1, 0, -1))

    def reset(self) -> None:
        """Return the allocator to its initial state for engine reuse.
        The device pool is kept as-is: stale block contents are harmless
        because prefill fully overwrites a slot's blocks before any read
        and positions past a slot's cursor are always masked."""
        self.tables[:] = 0
        self.pos[:] = 0
        self.active[:] = False
        self.n_blocks_of[:] = 0
        self.need[:] = 0
        self.committed = 0
        self.tables_version += 1
        self._free_slots = list(range(self.max_slots - 1, -1, -1))
        self._free_blocks = list(range(self.num_blocks - 1, 0, -1))

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def _need_blocks(self, prompt_len: int, max_new_tokens: int) -> int:
        total = min(prompt_len + max_new_tokens, self.capacity)
        return min(-(-max(total, prompt_len) // self.bs), self.max_blk)

    def can_admit(self, prompt_len: int, max_new_tokens: int = 0) -> bool:
        """True when a request fits *now*: a free slot plus enough
        uncommitted blocks for its whole token budget.  No overcommit —
        every admitted request's worst-case block need is reserved up
        front, so decode growth can never exhaust the pool mid-flight and
        a tightly-sized pool queues instead of crashing."""
        if not self._free_slots:
            return False
        need = self._need_blocks(prompt_len, max_new_tokens)
        return len(self._free_blocks) - self.committed >= need

    def used_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free_blocks)

    def written_tokens(self) -> int:
        return int(self.pos[self.active | (self.pos > 0)].sum())

    def _alloc_block(self) -> int:
        if not self._free_blocks:
            raise RuntimeError(
                "KV block pool exhausted: all "
                f"{self.num_blocks - 1} blocks in use (size the pool with "
                "num_blocks, or lower max_batch/capacity)")
        return self._free_blocks.pop()

    def alloc_slot(self, prompt_len: int, max_new_tokens: int = 0):
        """Claim a free slot + its prefill blocks, committing the rest of
        its token budget's blocks for later growth; cursor starts at the
        prompt length.  Returns (slot, block_ids)."""
        slot = self._free_slots.pop()
        nb0 = -(-prompt_len // self.bs)
        ids = [self._alloc_block() for _ in range(nb0)]
        self.tables[slot, :] = 0
        self.tables[slot, :nb0] = ids
        self.pos[slot] = prompt_len
        self.n_blocks_of[slot] = nb0
        self.need[slot] = self._need_blocks(prompt_len, max_new_tokens)
        self.committed += max(0, int(self.need[slot]) - nb0)
        self.tables_version += 1
        return slot, np.asarray(ids, np.int32)

    def grow_for_window(self, counts) -> None:
        """Before a decode window: every active slot must own every block
        its next ``counts[slot]`` token writes land in (the window may
        cross several block boundaries, so the whole window's blocks are
        reserved up front — the scan cannot call back into the allocator
        mid-flight).  Growth draws down the slot's admission-time
        commitment; write positions clamp at ``capacity - 1`` exactly like
        the decode path, so a window running past capacity needs no block
        beyond the last."""
        for slot in np.nonzero(self.active)[0]:
            n = int(counts[slot])
            if n <= 0:
                continue
            last = min(int(self.pos[slot]) + n - 1, self.capacity - 1)
            top = min(last // self.bs, self.max_blk - 1)
            while int(self.n_blocks_of[slot]) <= top:
                blk_i = int(self.n_blocks_of[slot])
                self.tables[slot, blk_i] = self._alloc_block()
                self.n_blocks_of[slot] = blk_i + 1
                if blk_i < int(self.need[slot]):
                    self.committed -= 1
                self.tables_version += 1

    def grow_for_write(self) -> None:
        """One-token lookahead: the per-token decode path's pre-step grow."""
        self.grow_for_window(self.active.astype(np.int32))

    def free_slot(self, slot: int) -> None:
        """Retire a slot: return its blocks and its unused commitment,
        zero its table row (trash)."""
        for j in range(int(self.n_blocks_of[slot])):
            self._free_blocks.append(int(self.tables[slot, j]))
        self.committed -= max(0, int(self.need[slot])
                              - int(self.n_blocks_of[slot]))
        self.tables[slot, :] = 0
        self.pos[slot] = 0
        self.active[slot] = False
        self.n_blocks_of[slot] = 0
        self.need[slot] = 0
        self.tables_version += 1
        self._free_slots.append(slot)


@dataclasses.dataclass
class _Slot:
    """One request occupying one decode slot of a :class:`_SlotEngine`."""

    req: ServeRequest
    out: List[int]
    first_token_t: float = 0.0


class _SlotEngine:
    """A clone's decode loop: max_batch slots over one paged KV pool.

    The engine replaces the cohort as the unit of batching.  A request is
    *admitted* into any free slot (``admit``) at any time — including while
    a decode step is in flight — and its prefill is folded into the next
    step, so late arrivals join mid-flight instead of waiting for the next
    cohort.  Slots retire independently at step granularity; their blocks
    return to the pool with no cache re-gather.
    """

    def __init__(self, backend, clone, kv: KVBlockPool, window: int = 1,
                 donate: bool = False):
        self.clone = clone
        self.kv = kv
        self.window = window
        # decode_slots (the per-token fn) is deliberately unused here: the
        # engine always dispatches windows (window=1 == one-step window);
        # benchmarks/decode_micro.py is the per-token fn's only caller
        self.prefill_into, _, self.decode_window = \
            backend.paged_fns(kv.bs, window, donate)
        self.slots: List[Optional[_Slot]] = [None] * kv.max_slots
        self.tok_host = np.zeros((kv.max_slots,), np.int32)
        self.joins: List[tuple] = []        # (slot, req, toks, blk_ids)
        self.submitted_joins: List[tuple] = []
        self.decode_rows: Optional[np.ndarray] = None
        self.decode_counts: Optional[np.ndarray] = None
        self._tables_dev = None             # device tables cache
        self._tables_ver = -1

    def device_tables(self):
        """Device copy of ``kv.tables``, re-uploaded only when the host
        table has been dirtied since the last step (alloc/grow/free/reset
        all bump ``tables_version``)."""
        if self._tables_ver != self.kv.tables_version:
            self._tables_dev = jnp.asarray(self.kv.tables)
            self._tables_ver = self.kv.tables_version
        return self._tables_dev

    def admit(self, req: ServeRequest, prompt_pad: int) -> None:
        toks = np.zeros((1, prompt_pad), np.int32)
        toks[0, :min(len(req.prompt), prompt_pad)] = req.prompt[:prompt_pad]
        slot, blk_ids = self.kv.alloc_slot(prompt_pad, req.max_new_tokens)
        self.joins.append((slot, req, jnp.asarray(toks), jnp.asarray(blk_ids)))

    def alive(self) -> bool:
        return any(s is not None for s in self.slots) or bool(self.joins)


@dataclasses.dataclass
class ServeReport:
    """One ``ClientHandler.run`` outcome: latency, elasticity, KV economy.

    ``kv_util`` is the time-averaged fraction of *reserved* KV memory that
    holds written tokens (sampled at every decode submission); contiguous
    cohorts reserve ``rows x capacity`` up front while the paged pool only
    reserves allocated blocks, which is the whole point of paging.
    ``kv_reserved_peak`` is the peak reservation in tokens.
    """

    completions: List[ServeCompletion]
    accepted: int
    rejected: int
    makespan_s: float
    p50_latency_s: float
    p99_latency_s: float
    p50_ttft_s: float
    tokens_per_s: float
    peak_secondaries: int
    scale_ups: int
    busy_energy_j: float
    pool_stats: Dict
    clone_samples: List[tuple]
    kv_mode: str = "paged"
    kv_util: float = 0.0
    kv_reserved_peak: int = 0

    def summary(self) -> str:
        """One-line digest (documented in docs/benchmarks.md)."""
        return (f"served={len(self.completions)} shed={self.rejected} "
                f"p50={self.p50_latency_s:.3f}s p99={self.p99_latency_s:.3f}s "
                f"ttft50={self.p50_ttft_s:.3f}s "
                f"tok/s={self.tokens_per_s:.1f} "
                f"kv={self.kv_mode} kv_util={self.kv_util:.0%} "
                f"peak_secondaries={self.peak_secondaries}")


class ClientHandler:
    """Event-driven continuous-batching server on an elastic clone pool.

    ``kv="paged"`` (default): each clone runs a :class:`_SlotEngine` —
    ``max_batch`` slots over a :class:`KVBlockPool`, per-slot decode
    cursors, mid-flight admission into free slots.  ``kv="contiguous"``
    keeps the PR-1 cohort path (shared cursor, step-boundary fusion only)
    as the measurable baseline for the paged design.
    """

    def __init__(self, backend, *, link: str = "wifi-local",
                 clone_type: str = "main", max_batch: int = 4,
                 queue_depth: int = 64, max_secondaries: int = 8,
                 min_secondaries: int = 0, work_per_clone: int = 1,
                 prompt_pad: int = 8, use_primary: bool = True,
                 provision_paused: bool = True,
                 kv: str = "paged", block_size: int = 8,
                 num_blocks: Optional[int] = None,
                 decode_window: int = 1, donate_kv: bool = False,
                 executor: Optional[Callable] = None,
                 pool: Optional[ClonePool] = None,
                 clock: Optional[VirtualClock] = None):
        if kv not in ("paged", "contiguous"):
            raise ValueError(f"kv must be 'paged' or 'contiguous': {kv!r}")
        if decode_window < 1:
            raise ValueError(f"decode_window must be >= 1: {decode_window}")
        if decode_window > 1 and kv != "paged":
            raise ValueError("decode_window > 1 requires kv='paged' (the "
                             "contiguous cohort path decodes per token)")
        if donate_kv and executor is None:
            # the default Venue executor re-runs a closure to stabilize its
            # timing; a donated pool is consumed by the first run
            raise ValueError("donate_kv needs an executor that runs each "
                             "dispatch exactly once (the default venue "
                             "executor re-times cheap calls)")
        self.kv_mode = kv
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.decode_window = decode_window
        self.donate_kv = donate_kv
        self.backend = backend
        # one timeline: adopt a supplied pool's clock (TTL accounting and
        # dispatch must share it), otherwise build pool around ours
        if pool is not None:
            if not getattr(pool.clock, "virtual", False):
                raise TypeError("ClientHandler needs a pool on a "
                                "VirtualClock")
            if clock is not None and clock is not pool.clock:
                raise ValueError("pool and clock disagree — pass one "
                                 "timeline")
            self.clock = pool.clock
            self.pool = pool
        else:
            self.clock = clock or VirtualClock()
            self.pool = ClonePool(link_name=link, clock=self.clock,
                                  max_clones=max_secondaries + 8)
        self.dispatcher = Dispatcher(self.pool, self.clock)
        self.queue = AdmissionQueue(queue_depth)
        self.autoscaler = QueueAutoscaler(
            self.pool, clone_type=clone_type, work_per_clone=work_per_clone,
            min_secondaries=min_secondaries, max_secondaries=max_secondaries)
        if provision_paused:     # paper §5.3: secondaries pre-created paused
            self.pool.provision(clone_type, max_secondaries)
        self.clone_type = clone_type
        self.max_batch = max_batch
        self.prompt_pad = prompt_pad
        self.use_primary = use_primary
        if not use_primary and max_secondaries < 1:
            raise ValueError("no primary and no secondaries: nothing can run")
        # executor(clone, fn, args) -> (value, venue_seconds); the default
        # runs on the clone's venue spec (tests inject fixed venue times)
        if executor is None:
            def executor(clone, fn, args):
                return Venue(clone.spec).execute(fn, *args)
        self.executor = executor
        self.busy_energy_j = 0.0
        self.tokens_emitted = 0
        self.ledger = SlotLedger()
        self.kv_samples: List[tuple] = []   # (written_tokens, reserved)
        self._kv_pools: Dict[int, KVBlockPool] = {}   # clone.cid -> pool

    # ---------------------------------------------------------------- clones
    def _free_clone(self):
        """Cheapest usable clone: warm first, then provisioning ones."""
        now = self.clock.now()
        cands = []
        if self.use_primary and not self.pool.primary.busy:
            cands.append((0.0, 0, self.pool.primary))
        for c in self.pool.running_secondaries(self.clone_type):
            if not c.busy:
                cands.append((self.autoscaler.clone_ready_delay(c, now),
                              c.cid, c))
        return min(cands)[2] if cands else None

    def _net_s(self, nbytes: int) -> float:
        return transfer_time(nbytes, self.pool.link)

    # ---------------------------------------------------------------- cohort
    def _start_cohort(self, batch: List[ServeRequest], clone):
        plen = self.prompt_pad
        toks = np.zeros((len(batch), plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, :min(len(r.prompt), plen)] = r.prompt[:plen]
        cohort = _Cohort(reqs=batch, clone=clone, plen=plen,
                         outs=[[] for _ in batch],
                         first_token_t=[0.0] * len(batch))
        clone.busy = True
        delay = (self.autoscaler.clone_ready_delay(clone, self.clock.now())
                 + self._net_s(toks.nbytes))
        task = self.dispatcher.submit(
            clone, self.backend.prefill, (self.backend.params,
                                          jnp.asarray(toks)),
            executor=self.executor, extra_delay=delay, label="prefill")
        self.busy_energy_j += task.venue_seconds * clone.spec.power_peak
        return task, cohort

    def _submit_decode(self, cohort: _Cohort):
        pos = jnp.int32(min(cohort.plen + cohort.step,
                            self.backend.capacity - 1))
        written = len(cohort.reqs) * min(cohort.plen + cohort.step + 1,
                                         self.backend.capacity)
        self.kv_samples.append((written,
                                len(cohort.reqs) * self.backend.capacity))
        task = self.dispatcher.submit(
            cohort.clone, self.backend.decode,
            (self.backend.params, cohort.cache, cohort.tok, pos),
            executor=self.executor,
            extra_delay=self._net_s(len(cohort.reqs) * 8), label="decode")
        self.busy_energy_j += task.venue_seconds * cohort.clone.spec.power_peak
        return task

    def _retire(self, cohort: _Cohort, completions: List[ServeCompletion]
                ) -> bool:
        """Emit current tokens; drop finished rows.  True while alive."""
        now = self.clock.now()
        tok = np.asarray(cohort.tok)[:, 0]
        keep = []
        for i, r in enumerate(cohort.reqs):
            cohort.outs[i].append(int(tok[i]))
            if len(cohort.outs[i]) == 1:
                cohort.first_token_t[i] = now
            if len(cohort.outs[i]) >= r.max_new_tokens:
                self.tokens_emitted += len(cohort.outs[i])
                completions.append(ServeCompletion(
                    r.rid, cohort.outs[i], r.arrival_t,
                    cohort.first_token_t[i], now, cohort.clone.spec.name))
            else:
                keep.append(i)
        if not keep:
            self.pool.release([cohort.clone])
            return False
        if len(keep) < len(cohort.reqs):      # leave at step granularity
            cohort.reqs = [cohort.reqs[i] for i in keep]
            cohort.outs = [cohort.outs[i] for i in keep]
            cohort.first_token_t = [cohort.first_token_t[i] for i in keep]
            cohort.tok = cohort.tok[np.asarray(keep, np.int32)]
            cohort.cache = self.backend.cache_take(cohort.cache, keep)
        return True

    # ------------------------------------------------------------- slots
    def _start_engine(self, clone) -> _SlotEngine:
        """Engine for ``clone``; the clone's KV pool is allocated once and
        reused (reset) across engine generations — no per-spawn zeros."""
        clone.busy = True
        kv = self._kv_pools.get(clone.cid)
        if kv is None:
            kv = KVBlockPool(self.backend, self.max_batch, self.block_size,
                             self.num_blocks)
            self._kv_pools[clone.cid] = kv
        else:
            kv.reset()
        return _SlotEngine(self.backend, clone, kv, self.decode_window,
                           self.donate_kv)

    def _submit_engine_step(self, engine: _SlotEngine):
        """One dispatched unit of engine work: fold every pending join's
        prefill into the step, then decode a multi-token *window* for all
        previously-active slots (one device dispatch for up to
        ``decode_window`` tokens per slot; rows at their budget park
        mid-window writes in the trash block).

        The dispatched closure is *pure* over its bound arguments (the
        Venue executor re-runs it to stabilize timing), so all block/slot
        bookkeeping happens here on the host before submission.
        """
        joins, engine.joins = engine.joins, []
        engine.submitted_joins = joins
        kv = engine.kv
        rows = np.nonzero(kv.active)[0]
        do_decode = rows.size > 0
        engine.decode_rows = rows if do_decode else None
        # tokens each slot will emit this window: min(window, budget left)
        counts = np.zeros((kv.max_slots,), np.int32)
        if do_decode:
            for slot in rows:
                s = engine.slots[slot]
                counts[slot] = min(engine.window,
                                   s.req.max_new_tokens - len(s.out))
            kv.grow_for_window(counts)       # whole window's blocks up front
            # written-token sample: writes past capacity pin to the last
            # cell (same clamp the host fold applies to kv.pos), so they
            # must not count as newly written either
            eff = np.minimum(counts, np.maximum(kv.capacity - kv.pos, 0))
            written = kv.written_tokens() + int(eff.sum())
            self.kv_samples.append((written, kv.used_blocks() * kv.bs))
        engine.decode_counts = counts
        tables = engine.device_tables()      # re-uploaded only when dirty
        pos = jnp.asarray(np.minimum(kv.pos, self.backend.capacity - 1))
        tok = jnp.asarray(engine.tok_host[:, None])
        steps_left = jnp.asarray(counts)
        prefill_into = engine.prefill_into
        decode_window = engine.decode_window
        nbytes = 8 * int(counts.sum())
        join_batch = None
        if joins:
            # joins landing at the same boundary prefill as ONE batched
            # call, padded to a power-of-two bucket so the prefill only
            # ever compiles for log2(max_batch) join counts.  Pad rows
            # scatter nowhere: slot id ``max_slots`` is out of range
            # (state-row update dropped) and block id 0 is the trash block.
            j = len(joins)
            jpad = 1 << (j - 1).bit_length()
            toks = jnp.concatenate(
                [t for _, _, t, _ in joins]
                + [jnp.zeros((jpad - j,) + joins[0][2].shape[1:],
                             jnp.int32)] * (jpad > j), axis=0)
            blks = jnp.concatenate(
                [jnp.stack([b for _, _, _, b in joins])]
                + [jnp.zeros((jpad - j, joins[0][3].shape[0]),
                             jnp.int32)] * (jpad > j), axis=0)
            slots = jnp.asarray([s for s, _, _, _ in joins]
                                + [kv.max_slots] * (jpad - j), jnp.int32)
            join_batch = (toks, blks, slots)
            nbytes += int(toks.nbytes)

        def step_fn(params, pool, tok, pos, steps_left, tables):
            firsts = None
            if join_batch is not None:
                toks, blks, slots = join_batch
                firsts, pool = prefill_into(params, toks, pool, blks, slots)
            nxt = None
            if do_decode:
                nxt, pool = decode_window(params, pool, tok, pos,
                                          steps_left, tables)
            return firsts, nxt, pool

        delay = (self.autoscaler.clone_ready_delay(engine.clone,
                                                   self.clock.now())
                 + self._net_s(nbytes))
        task = self.dispatcher.submit(
            engine.clone, step_fn,
            (self.backend.params, kv.pool, tok, pos, steps_left, tables),
            executor=self.executor, extra_delay=delay,
            label="step" if do_decode else "prefill")
        self.busy_energy_j += (task.venue_seconds
                               * engine.clone.spec.power_peak)
        return task

    def _engine_step_done(self, engine: _SlotEngine, task,
                          completions: List[ServeCompletion]) -> bool:
        """Fold a completed step back into host state.  True while alive."""
        now = self.clock.now()
        firsts, nxt, pool = task.value
        kv = engine.kv
        kv.pool = pool
        firsts = [] if firsts is None else np.asarray(firsts)
        for (slot, req, _, _), ft in zip(engine.submitted_joins, firsts):
            t0 = int(ft)
            engine.slots[slot] = _Slot(req, [t0], now)
            engine.tok_host[slot] = t0
            kv.active[slot] = True
        engine.submitted_joins = []
        if engine.decode_rows is not None and nxt is not None:
            nxt = np.asarray(nxt)                       # (S, window)
            rows = engine.decode_rows
            n = engine.decode_counts[rows]              # >= 1 per active row
            # vectorized fold: last live token and the capacity clamp via
            # fancy indexing (the clamp: past capacity the write position
            # pins to the last slot, like the contiguous path, so the
            # written-token count must not keep growing either)
            engine.tok_host[rows] = nxt[rows, n - 1]
            kv.pos[rows] = np.minimum(kv.pos[rows] + n, kv.capacity)
            for slot, row, k in zip(rows, nxt[rows].tolist(), n.tolist()):
                engine.slots[slot].out.extend(row[:k])
            engine.decode_rows = None
        for slot, s in enumerate(engine.slots):   # evict at step granularity
            if s is not None and len(s.out) >= s.req.max_new_tokens:
                self.tokens_emitted += len(s.out)
                completions.append(ServeCompletion(
                    s.req.rid, s.out, s.req.arrival_t, s.first_token_t,
                    now, engine.clone.spec.name))
                engine.slots[slot] = None
                kv.free_slot(slot)
        return engine.alive()

    # ------------------------------------------------------------------ run
    def run(self, requests: List[ServeRequest], *,
            drain_idle_s: float = 0.0) -> ServeReport:
        """Serve ``requests`` on the virtual timeline; returns a report.

        The loop (both KV modes): admit due arrivals into the bounded
        queue; in paged mode, *offer queued requests to partially-full
        in-flight engines first* (the :class:`~repro.core.scheduler.
        SlotLedger` admission policy — mid-flight joins); autoscale on the
        residual demand; start new engines/cohorts on free clones; then
        advance time to the next task completion or arrival.
        """
        paged = self.kv_mode == "paged"
        reqs = sorted(requests, key=lambda r: r.arrival_t)
        t_start = self.clock.now()
        i = 0
        inflight: Dict[object, object] = {}        # task -> engine | cohort
        engines: Dict[int, _SlotEngine] = {}       # id -> live engine
        completions: List[ServeCompletion] = []

        while True:
            now = self.clock.now()
            while i < len(reqs) and reqs[i].arrival_t <= now + 1e-12:
                self.queue.offer(reqs[i], now)
                i += 1
            if paged and engines:
                # mid-flight joins: fill open slots of in-flight engines
                # before counting residual demand or spawning new ones
                # (block-commitment checked per request via ``fits``)
                for key, eng in engines.items():
                    self.ledger.update(key, eng.kv.free_slots)
                # admit via on_assign so each fits() check sees the block
                # commitments of earlier assignments in the same round
                self.ledger.assign(
                    self.queue,
                    fits=lambda key, r: engines[key].kv.can_admit(
                        self.prompt_pad, r.max_new_tokens),
                    on_assign=lambda key, r: engines[key].admit(
                        r, self.prompt_pad))
            # demand in cohort units: queued requests coalesce into batches
            queued_cohorts = -(-self.queue.depth // self.max_batch)
            self.autoscaler.step(now, queued_cohorts, len(inflight))
            # spawn engines/cohorts while a clone is free
            while self.queue.depth > 0:
                clone = self._free_clone()
                if clone is None:
                    break
                if paged:
                    engine = self._start_engine(clone)
                    n = 0
                    while (n < self.max_batch and self.queue.depth > 0
                           and engine.kv.can_admit(
                               self.prompt_pad,
                               self.queue.peek().max_new_tokens)):
                        engine.admit(self.queue.take(1)[0], self.prompt_pad)
                        n += 1
                    if n == 0:
                        raise RuntimeError(
                            "KV block pool too small to admit one request "
                            f"(num_blocks={engine.kv.num_blocks}, "
                            f"prompt_pad={self.prompt_pad}, "
                            f"block_size={self.block_size})")
                    engines[id(engine)] = engine
                    self.ledger.update(id(engine), engine.kv.free_slots)
                    inflight[self._submit_engine_step(engine)] = engine
                else:
                    task, cohort = self._start_cohort(
                        self.queue.take(self.max_batch), clone)
                    inflight[task] = cohort

            if inflight:
                # bound the wait so due arrivals are admitted on time
                next_arrival = reqs[i].arrival_t if i < len(reqs) else None
                first_done = min(t.done_at for t in inflight)
                if next_arrival is not None and next_arrival < first_done:
                    self.clock.advance_to(next_arrival)
                    continue
                for task in self.dispatcher.wait_any(list(inflight)):
                    unit = inflight.pop(task)
                    if paged:
                        if self._engine_step_done(unit, task, completions):
                            inflight[self._submit_engine_step(unit)] = unit
                        else:
                            engines.pop(id(unit), None)
                            self.ledger.drop(id(unit))
                            self.pool.release([unit.clone])
                    else:
                        cohort = unit
                        tok, cohort.cache = task.value
                        cohort.tok = tok[:, None]
                        if cohort.phase == "prefill":
                            cohort.phase = "decode"
                        else:
                            cohort.step += 1
                        if self._retire(cohort, completions):
                            inflight[self._submit_decode(cohort)] = cohort
            elif i < len(reqs):
                self.clock.advance_to(reqs[i].arrival_t)
            elif self.queue.depth > 0:
                raise RuntimeError("requests queued but no clone can run "
                                   "(max_secondaries too small?)")
            else:
                break

        if drain_idle_s > 0.0:       # let idle TTLs pause the secondaries
            self.clock.advance(drain_idle_s)
            self.autoscaler.step(self.clock.now(), 0, 0)

        lat = np.array([c.latency_s for c in completions]) \
            if completions else np.zeros(1)
        ttft = np.array([c.ttft_s for c in completions]) \
            if completions else np.zeros(1)
        makespan = self.clock.now() - t_start - drain_idle_s
        utils = [w / r for w, r in self.kv_samples if r > 0]
        return ServeReport(
            completions=completions,
            accepted=self.queue.accepted,
            rejected=self.queue.rejected,
            makespan_s=makespan,
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            p50_ttft_s=float(np.percentile(ttft, 50)),
            tokens_per_s=self.tokens_emitted / max(makespan, 1e-9),
            peak_secondaries=self.autoscaler.peak_secondaries,
            scale_ups=self.autoscaler.scale_ups,
            busy_energy_j=self.busy_energy_j,
            pool_stats=dict(self.pool.stats),
            clone_samples=list(self.autoscaler.samples),
            kv_mode=self.kv_mode,
            kv_util=float(np.mean(utils)) if utils else 0.0,
            kv_reserved_peak=max((r for _, r in self.kv_samples),
                                 default=0))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--policy", default="exec_time")
    ap.add_argument("--handler", action="store_true",
                    help="serve through the event-driven ClientHandler")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson offered load (req/s) for --handler")
    ap.add_argument("--kv", choices=["paged", "contiguous"], default="paged",
                    help="KV cache mode for --handler")
    ap.add_argument("--window", type=int, default=1,
                    help="decode window: tokens fused per device dispatch")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if args.handler:
        backend = LMBackend(cfg, capacity=64)
        handler = ClientHandler(backend, max_batch=args.batch, kv=args.kv,
                                decode_window=args.window)
        reqs = poisson_arrivals(args.rate, args.requests,
                                prompt_len=8, vocab=cfg.vocab_size,
                                max_new_tokens=args.new_tokens)
        report = handler.run(reqs, drain_idle_s=60.0)
        print(report.summary())
        print("pool:", report.pool_stats)
        return

    eng = ServingEngine(cfg, policy=Policy(args.policy))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=12,
                                    dtype=np.int32), args.new_tokens)
            for i in range(args.requests)]
    done = []
    for i in range(0, len(reqs), args.batch):
        comps = eng.serve_batch(reqs[i:i + args.batch])
        done.extend(comps)
        c = comps[0]
        print(f"batch {i // args.batch}: venue={c.prefill_venue} "
              f"latency={c.latency_s:.3f}s tokens={c.tokens[:6]}...")
    print("stats:", eng.stats)


if __name__ == "__main__":
    main()
