"""Serving driver: ThinkAir placement / escalation / parallelization for LM
inference.

Each request batch is a remoteable method invocation: the ExecutionController
decides placement (local small venue vs cloud clones) per batch from profiled
history; long-context requests whose KV-cache working set exceeds the default
clone's memory are escalated to a bigger clone type (the paper's
OutOfMemoryError path); prefill for large batches can be split across k
clones (the paper's parallelization path).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import (ClonePool, ExecutionController, Policy,
                        RemoteableMethod, split_batch)
from repro.core.venues import pytree_bytes
from repro.launch import steps as S
from repro.models import model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prefill_venue: str
    decode_venue: str
    latency_s: float
    escalations: int


class ServingEngine:
    """Batched prefill + decode with ThinkAir placement decisions."""

    def __init__(self, cfg, *, policy: Policy = Policy.EXEC_TIME,
                 link: str = "wifi-local", max_batch: int = 8,
                 capacity: int = 256):
        self.cfg = cfg
        self.max_batch = max_batch
        self.capacity = capacity
        self.ctx = S.make_context(None,
                                  moe_capacity_factor=(
                                      cfg.n_experts / cfg.top_k
                                      if cfg.is_moe else 1.25))
        self.params = model.init(cfg, jax.random.PRNGKey(0))
        self.ec = ExecutionController(policy=policy, link=link)
        self.ec.pool.provision("main", 8)       # paused secondaries (paper)
        cap = self.capacity

        def prefill_fn(params, tokens):
            logits, cache = model.forward(cfg, params, {"tokens": tokens},
                                          self.ctx, "prefill",
                                          cache_capacity=cap)
            return jnp.argmax(logits, -1), cache

        def decode_fn(params, cache, tokens, pos):
            logits, cache = model.decode_step(cfg, params, cache, tokens,
                                              pos, self.ctx)
            return jnp.argmax(logits, -1), cache

        # KV working set drives escalation: bytes ~ cache size
        def prefill_mem(params, tokens):
            b = tokens.shape[0]
            return pytree_bytes(model.abstract_cache(cfg, b, cap))

        self.rm_prefill = RemoteableMethod(
            "serve_prefill", prefill_fn, size_fn=lambda p, t: t.size,
            split_fn=self._split_prefill, merge_fn=self._merge_prefill,
            mem_fn=prefill_mem)
        self.rm_decode = RemoteableMethod(
            "serve_decode", decode_fn,
            size_fn=lambda p, c, t, pos: t.shape[0])
        self.stats = {"requests": 0, "batches": 0, "offloaded": 0,
                      "escalations": 0}

    @staticmethod
    def _split_prefill(args, k):
        params, tokens = args
        tok_shards = np.array_split(np.asarray(tokens), k, axis=0)
        return [(params, jnp.asarray(t)) for t in tok_shards]

    @staticmethod
    def _merge_prefill(values):
        toks = jnp.concatenate([v[0] for v in values], axis=0)
        caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                              *[v[1] for v in values])
        return toks, caches

    def serve_batch(self, reqs: List[Request], *, n_clones: int = 1,
                    force: Optional[str] = None) -> List[Completion]:
        t0 = time.time()
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
        res_p = self.ec.execute(self.rm_prefill, self.params,
                                jnp.asarray(toks), n_clones=n_clones,
                                force=force)
        next_tok, cache = res_p.value
        out = [list() for _ in reqs]
        steps_needed = max(r.max_new_tokens for r in reqs)
        tok = next_tok[:, None]
        total_time = res_p.time_s
        decode_venue = "-"
        for step_i in range(steps_needed):
            for i in range(len(reqs)):
                out[i].append(int(tok[i, 0]))
            pos = jnp.int32(min(plen + step_i, self.capacity - 1))
            res_d = self.ec.execute(self.rm_decode, self.params, cache, tok,
                                    pos, force=force)
            tok, cache = res_d.value
            tok = tok[:, None]
            total_time += res_d.time_s
            decode_venue = res_d.venue
        self.stats["requests"] += len(reqs)
        self.stats["batches"] += 1
        self.stats["offloaded"] += int(res_p.offloaded)
        self.stats["escalations"] += res_p.escalations
        wall = time.time() - t0
        return [Completion(r.rid, out[i], res_p.venue, decode_venue,
                           total_time, res_p.escalations)
                for i, r in enumerate(reqs)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--policy", default="exec_time")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    eng = ServingEngine(cfg, policy=Policy(args.policy))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=12,
                                    dtype=np.int32), args.new_tokens)
            for i in range(args.requests)]
    done = []
    for i in range(0, len(reqs), args.batch):
        comps = eng.serve_batch(reqs[i:i + args.batch])
        done.extend(comps)
        c = comps[0]
        print(f"batch {i // args.batch}: venue={c.prefill_venue} "
              f"latency={c.latency_s:.3f}s tokens={c.tokens[:6]}...")
    print("stats:", eng.stats)


if __name__ == "__main__":
    main()
