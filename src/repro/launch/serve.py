"""Serving stack: ThinkAir's Client Handler for LM inference.

Two layers share one model binding (``LMBackend``):

``ServingEngine`` — the batch-at-a-time path (seed behaviour).  Each request
batch is a remoteable method invocation: the ExecutionController decides
placement (local small venue vs cloud clones) per batch from profiled
history; long-context requests whose KV-cache working set exceeds the
default clone's memory are escalated to a bigger clone type (the paper's
OutOfMemoryError path); prefill for large batches can be split across k
clones (the paper's parallelization path).

``ClientHandler`` — the event-driven continuous-batching server (paper
§5.2-§5.3, the tentpole of the Client Handler refactor).  Requests arrive
on a shared :class:`~repro.core.clock.VirtualClock`, pass admission control
(:class:`~repro.core.scheduler.AdmissionQueue`), and are formed into
*cohorts* of up to ``max_batch`` requests.  Each cohort's prefill and every
decode step is a non-blocking :class:`~repro.core.dispatch.Dispatcher` task
on one clone, so cohorts on different clones genuinely overlap on the
timeline.  Requests **leave** their cohort at decode-step granularity the
moment they hit their token budget (the cohort's KV cache shrinks in
place), and new arrivals **enter** service at the next step boundary on any
free clone — they never wait for a whole batch to drain.  A
:class:`~repro.core.scheduler.FleetAutoscaler` provisions and TTL-pauses
secondaries through the ClonePool lifecycle, which makes the paper's
elasticity claim measurable as p50/p99 latency and tokens/s under Poisson
offered load (see ``benchmarks/serving_load.py``).

The fleet is **heterogeneous** (ADR-004): ``ClientHandler(fleet=[...])``
serves across several paper-Table-1 clone types at once.  Demand is
bucketed per tenant/priority class and per KV footprint; a
:class:`~repro.core.scheduler.PlacementEngine` places each bucket on a
tier by cost/energy/latency (``placement_policy``), and a request whose
prompt+window KV demand exceeds its tier's block pool is *escalated* up
the :meth:`~repro.core.clones.ClonePool.escalate_type` ladder — the
serving-layer analogue of the paper's OutOfMemoryError -> bigger-VM flow
(§5.4).  Per-type block pools scale with the tier's memory ladder, busy
energy is billed chips-aware through
:meth:`~repro.core.energy.TpuEnergyModel.busy_j`, and the
:class:`ServeReport` carries the fleet economics (per-type clone-seconds,
$-cost, per-type energy, the served fleet mix).

KV cache modes: the default ``kv="paged"`` path batches at *slot*
granularity — each clone runs a :class:`_SlotEngine` whose requests each
own a per-slot decode cursor and a row of a block table over a fixed
:class:`KVBlockPool`; a late arrival is prefilled into any free slot of an
in-flight engine at the next decode step (no step-boundary fusion, no
``cache_take`` re-gather on retire).  ``kv="contiguous"`` keeps the PR-1
cohort path — one shared cursor, fusion only at the same step boundary —
as the measurable baseline (see ``benchmarks/serving_load.py`` and
``docs/architecture.md``).  Weights are resident on the clones (serving
fleet), so per-request network cost is prompt/token traffic only — unlike
the offload path, which ships the method's whole state.

The paged pool is a **refcounted copy-on-write prefix cache** with
**preemption-aware slot scheduling** (ADR-003): prompt blocks are
content-indexed so shared prefixes map into new slots at refcount + 1
instead of re-prefilling (the first divergent block is copied-on-write on
device), admission reserves only prompt blocks, and a pool exhausting
mid-decode evicts a victim slot for prefix-accelerated restore instead of
raising — overload degrades into latency, not failure (paper §5's
many-users elasticity claim at the KV level).  ``prefix_cache=False``
keeps the unshared path as the measurable baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import (ClonePool, ExecutionController, Policy,
                        RemoteableMethod, TpuEnergyModel)
from repro.core.clock import VirtualClock
from repro.core.clones import (CLONE_TYPES, KV_SCALE_BY_CLONE_TYPE,
                               PAUSE_IDLE_TTL, CircuitBreaker)
from repro.core.dispatch import Dispatcher
from repro.core.faults import CloneFault, FaultInjector
from repro.core.gateway import StreamingGateway
from repro.core.scheduler import (AdmissionQueue, FleetAutoscaler,
                                  PlacementEngine, ServeCompletion,
                                  ServeRequest, SlotLedger, poisson_arrivals)
from repro.core.venues import (LINKS, Venue, kv_block_bytes, pytree_bytes,
                               transfer_time)
from repro.launch import steps as S
from repro.models import model


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (n >= 1): the dispatch bucket size.

    Every variable-size batched dispatch (join prefill rows, CoW pairs,
    suffix rows and steps, prefill chunk steps) pads to one of these so
    each jitted graph only ever compiles O(log) shape variants.
    """
    if n < 1:
        raise ValueError(f"bucket size needs n >= 1: {n}")
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prefill_venue: str
    decode_venue: str
    latency_s: float
    escalations: int


class LMBackend:
    """Model binding: params + jitted prefill/decode + cache batch surgery.

    ``draft`` arms cross-tier speculative decoding (ADR-008): a
    :class:`~repro.configs.ModelConfig` binds a reduced-cost draft model
    sharing the target's vocab (its own params, context, and paged pool);
    the string ``"oracle"`` aliases the target itself as its own draft —
    the acceptance-rate-1.0 harness benchmarks and tests corrupt
    deterministically.  ``None`` (default) leaves speculation off.
    """

    def __init__(self, cfg, capacity: int = 256, draft=None):
        self.cfg = cfg
        self.capacity = capacity
        self.ctx = S.make_context(None,
                                  moe_capacity_factor=(
                                      cfg.n_experts / cfg.top_k
                                      if cfg.is_moe else 1.25))
        self.params = model.init(cfg, jax.random.PRNGKey(0))
        self.draft_cfg = None
        self.draft_params = None
        self.draft_ctx = None
        if draft == "oracle":
            self.draft_cfg, self.draft_ctx = cfg, self.ctx
            self.draft_params = self.params
        elif draft is not None:
            if draft.vocab_size != cfg.vocab_size:
                raise ValueError(
                    "draft model must share the target's vocab "
                    f"({draft.vocab_size} != {cfg.vocab_size}): acceptance "
                    "compares token ids directly")
            self.draft_cfg = draft
            self.draft_ctx = S.make_context(None,
                                            moe_capacity_factor=(
                                                draft.n_experts / draft.top_k
                                                if draft.is_moe else 1.25))
            self.draft_params = model.init(draft, jax.random.PRNGKey(7))
        cap = capacity

        def prefill_fn(params, tokens):
            logits, cache = model.forward(cfg, params, {"tokens": tokens},
                                          self.ctx, "prefill",
                                          cache_capacity=cap)
            return jnp.argmax(logits, -1), cache

        def decode_fn(params, cache, tokens, pos):
            logits, cache = model.decode_step(cfg, params, cache, tokens,
                                              pos, self.ctx)
            return jnp.argmax(logits, -1), cache

        self.prefill = jax.jit(prefill_fn)
        self.decode = jax.jit(decode_fn)
        # locate each cache leaf's batch/capacity axes by diffing shapes
        self._batch_axis, self._cap_axis = model.cache_axes(cfg)
        self._paged_fns: Dict[tuple, tuple] = {}      # (bs, donate)
        self._paged_win_fns: Dict[tuple, object] = {}  # (bs, window, donate)
        self._paged_sfx_fns: Dict[tuple, object] = {}  # (bs, T, C, donate)
        self._paged_mix_fns: Dict[tuple, object] = {}  # (bs, C, T, donate)
        self._copy_fns: Dict[bool, object] = {}        # donate -> fn
        self._spec_fns: Dict[tuple, tuple] = {}        # (bs, Tc, K)

    @property
    def supports_chunked(self) -> bool:
        """Whether chunked prefill / mixed dispatch cover this config."""
        return model.supports_chunked_prefill(self.cfg)

    @property
    def supports_speculative(self) -> bool:
        """Whether a draft model is bound (and the target can run the
        chunked verify pass — same layer requirement as ADR-005)."""
        return self.draft_cfg is not None and self.supports_chunked

    @property
    def draft_cost_ratio(self) -> float:
        """Draft/target parameter-count ratio — the *informational*
        per-step cost ratio.  At smoke scale this is embedding-dominated
        (vocab 256), so benchmarks model venue time with an explicit
        ``--draft-cost`` instead (docs/benchmarks.md)."""
        if self.draft_params is None:
            return 1.0
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(self.params))
        d = sum(int(np.prod(x.shape))
                for x in jax.tree.leaves(self.draft_params))
        return d / max(n, 1)

    def cache_mem_bytes(self, batch: int) -> int:
        return pytree_bytes(model.abstract_cache(self.cfg, batch,
                                                 self.capacity))

    def cache_take(self, cache, keep_idx) -> Dict:
        """Shrink a cohort cache to the surviving batch rows."""
        idx = jnp.asarray(np.asarray(keep_idx, np.int32))

        def take(leaf, ax):
            return leaf if ax is None else jnp.take(leaf, idx, axis=ax)

        return jax.tree.map(take, cache, self._batch_axis)

    # ---------------------------------------------------------------- paged
    def init_paged_pool(self, max_slots: int, num_blocks: int,
                        block_size: int):
        """Zero KV block pool + per-slot state rows (block 0 = trash)."""
        return model.init_paged_cache(self.cfg, max_slots, num_blocks,
                                      block_size)

    def init_draft_pool(self, max_slots: int, num_blocks: int,
                        block_size: int):
        """Zero paged pool for the *draft* model, same block geometry as
        the target pool so the two share one set of block tables
        (ADR-008: no extra host bookkeeping for the draft side)."""
        return model.init_paged_cache(self.draft_cfg, max_slots, num_blocks,
                                      block_size)

    def spec_draft_fn(self, block_size: int, catchup_steps: int,
                      k_max: int):
        """Jitted draft half of a speculative round (ADR-008).

        ``fn(dparams, dpool, ctoks (S,Tc), cpos0 (S,), n_c (S,), tok
        (S,1), pos (S,), k_live (S,), tables (S,M))`` runs the draft
        model's catch-up (teacher-forcing the ``n_c`` committed target
        tokens it has not yet ingested, from each row's draft cursor
        ``cpos0``) plus up to ``k_max`` greedy proposal steps per row in
        ONE dispatch (:func:`model.draft_loop`), returning ``(drafts
        (S, k_max), new_dpool)``.  Cached per (block_size,
        catchup_steps, k_max); callers bucket ``catchup_steps`` to
        powers of two so only O(log) variants compile."""
        key = ("draft", block_size, catchup_steps, k_max)
        fn = self._spec_fns.get(key)
        if fn is not None:
            return fn
        dcfg, dctx, capacity = self.draft_cfg, self.draft_ctx, self.capacity

        def draft(dparams, dpool, ctoks, cpos0, n_c, tok, pos, k_live,
                  tables):
            return model.draft_loop(
                dcfg, dparams, dpool, ctoks, cpos0, n_c, tok, pos, k_live,
                dctx, block_tables=tables, block_size=block_size,
                catchup_steps=catchup_steps, num_steps=k_max,
                capacity=capacity)

        fn = jax.jit(draft)
        self._spec_fns[key] = fn
        return fn

    def spec_verify_fn(self, block_size: int):
        """Jitted verify half of a speculative round (ADR-008).

        ``fn(params, pool, toks (S, K+1), pos0 (S,), n_live (S,), tables
        (S,M))`` scores each row's current token plus its ``n_live - 1``
        draft proposals in ONE chunked teacher-forced pass over the
        *target* (:func:`model.verify_window` through the GQA-fused
        ``paged_verify`` kernel), returning ``(greedy (S, K+1),
        new_pool)`` — the grid the host accepts with
        :func:`model.spec_accept`."""
        key = ("verify", block_size)
        fn = self._spec_fns.get(key)
        if fn is not None:
            return fn
        cfg, ctx, capacity = self.cfg, self.ctx, self.capacity

        def verify(params, pool, toks, pos0, n_live, tables):
            return model.verify_window(
                cfg, params, pool, toks, pos0, n_live, ctx,
                block_tables=tables, block_size=block_size,
                capacity=capacity)

        fn = jax.jit(verify)
        self._spec_fns[key] = fn
        return fn

    def paged_fns(self, block_size: int, window: int = 1,
                  donate: bool = False):
        """(prefill_into, decode_slots, decode_window) jitted fns.

        ``prefill_into(params, toks (J,P), pool, blk_ids (J,nb0), slots
        (J,))`` prefills J prompts in one batched call and scatters each
        row's KV into its pool blocks (and its recurrent state into its
        slot row), returning ``(first_tokens (J,), new_pool)``.  Joins
        landing at the same step boundary therefore cost one prefill, like
        a contiguous cohort.  Rows whose slot id is out of range (the
        power-of-two bucket padding) scatter nowhere: their state-row
        update is dropped and their KV lands in the trash block.

        ``decode_slots(params, pool, tok (S,1), pos (S,), tables (S,M))``
        runs one decode step for every slot at its own cursor, returning
        ``(next_tokens (S,), new_pool)``.  Inactive slots must carry
        ``pos=0`` and an all-zero table row so their writes land in the
        trash block.

        ``decode_window(params, pool, tok (S,1), pos (S,), steps_left (S,),
        tables (S,M))`` is the flash-decoding fast path: ``window`` greedy
        steps fused into one ``lax.scan`` dispatch (``model.decode_loop``),
        returning ``(tokens (S, window), new_pool)``.  Rows exhaust their
        ``steps_left`` mid-window and park further writes in the trash
        block until the host-side boundary.

        ``donate=True`` adds ``donate_argnums`` on the pool so each step
        updates the KV pool in place instead of deep-copying it.  A donated
        call *consumes* its pool argument — callers whose executor re-runs
        a closure (the default simulated Venue re-times cheap calls) must
        keep ``donate=False``; see docs/architecture.md ADR-002.
        """
        # prefill_into / decode_slots don't depend on the window: cache
        # them under (bs, donate) so handlers with different windows share
        # one compiled prefill graph; only decode_window is window-keyed
        base_key = (block_size, donate)
        win_key = (block_size, window, donate)
        if base_key in self._paged_fns and win_key in self._paged_win_fns:
            return self._paged_fns[base_key] + (self._paged_win_fns[win_key],)
        cfg, ctx = self.cfg, self.ctx
        b_ax, c_ax = self._batch_axis, self._cap_axis
        capacity = self.capacity

        def prefill_into(params, toks, pool, blk_ids, slots):
            j, nb0 = blk_ids.shape
            logits, pcache = model.forward(
                cfg, params, {"tokens": toks}, ctx, "prefill",
                cache_capacity=nb0 * block_size)
            flat_ids = blk_ids.reshape(-1)

            def scatter(pool_leaf, pre, bax, cax):
                if cax is None:                      # per-slot state rows
                    lp = jnp.moveaxis(pool_leaf, bax, 0)
                    rows = jnp.moveaxis(pre, bax, 0)
                    return jnp.moveaxis(lp.at[slots].set(rows, mode="drop"),
                                        0, bax)
                lp = jnp.moveaxis(pool_leaf, (bax, cax), (0, 1))
                pr = jnp.moveaxis(pre, (bax, cax), (0, 1))
                pr = pr.reshape((j * nb0, block_size) + pr.shape[2:])
                return jnp.moveaxis(lp.at[flat_ids].set(pr), (0, 1),
                                    (bax, cax))

            pool = jax.tree.map(scatter, pool, pcache, b_ax, c_ax)
            return jnp.argmax(logits, -1), pool

        def decode_slots(params, pool, tok, pos, tables):
            logits, pool = model.decode_step(
                cfg, params, pool, tok, pos, ctx, block_tables=tables,
                block_size=block_size)
            return jnp.argmax(logits, -1), pool

        def decode_window(params, pool, tok, pos, steps_left, tables):
            return model.decode_loop(
                cfg, params, pool, tok, pos, steps_left, ctx,
                block_tables=tables, block_size=block_size,
                num_steps=window, capacity=capacity)

        if base_key not in self._paged_fns:
            self._paged_fns[base_key] = (
                jax.jit(prefill_into, donate_argnums=(2,)),
                jax.jit(decode_slots, donate_argnums=(1,))) if donate else (
                jax.jit(prefill_into), jax.jit(decode_slots))
        self._paged_win_fns[win_key] = jax.jit(
            decode_window, donate_argnums=(1,) if donate else ())
        return self._paged_fns[base_key] + (self._paged_win_fns[win_key],)

    def prefill_window_fn(self, block_size: int, num_steps: int,
                          donate: bool = False, chunk: int = 0):
        """Jitted suffix prefill for prefix-hit / restored rows.

        ``fn(params, pool, toks (J,T), pos0 (J,), n_tok (J,), tables
        (J,M)) -> (first_tokens (J,), new_pool)`` — a teacher-forced
        :func:`model.prefill_loop` scan: row i writes its ``n_tok[i]``
        suffix tokens from position ``pos0[i]`` through its block table
        and returns the greedy token after its last suffix position.
        Rows with ``n_tok == 0`` (bucket padding) park in the trash
        block.

        ``chunk > 0`` switches to the chunked-prefill path
        (:func:`model.prefill_chunks`, ADR-005): the scan advances
        ``chunk`` tokens per step through the paged chunk kernel, so the
        same ``num_steps``-token suffix costs ⌈num_steps/chunk⌉
        sequential steps — token-identical to the stepwise scan.  Cached
        per (block_size, num_steps, chunk, donate), so suffix batches
        bucketed to powers of two compile O(log) variants."""
        key = (block_size, num_steps, chunk, donate)
        fn = self._paged_sfx_fns.get(key)
        if fn is not None:
            return fn
        cfg, ctx, capacity = self.cfg, self.ctx, self.capacity

        if chunk > 0:
            if not self.supports_chunked:
                raise ValueError("chunked prefill requires all-attention "
                                 "windowless layers (see "
                                 "model.supports_chunked_prefill)")
            n_chunks = -(-num_steps // chunk)

            def prefill_window(params, pool, toks, pos0, n_tok, tables):
                return model.prefill_chunks(
                    cfg, params, pool, toks, pos0, n_tok, ctx,
                    block_tables=tables, block_size=block_size,
                    chunk=chunk, num_steps=n_chunks, capacity=capacity)
        else:
            def prefill_window(params, pool, toks, pos0, n_tok, tables):
                return model.prefill_loop(
                    cfg, params, pool, toks, pos0, n_tok, ctx,
                    block_tables=tables, block_size=block_size,
                    num_steps=num_steps, capacity=capacity)

        fn = jax.jit(prefill_window,
                     donate_argnums=(1,) if donate else ())
        self._paged_sfx_fns[key] = fn
        return fn

    def mixed_fn(self, block_size: int, chunk: int, num_steps: int,
                 donate: bool = False):
        """Jitted unified mixed prefill/decode engine step (ADR-005).

        ``fn(params, pool, tok (S,1), pos (S,), steps_left (S,), tables
        (S,M), stoks (J,T), spos (J,), sn (J,), stabs (J,M)) ->
        (tokens (S, num_steps), first_tokens (J,), new_pool)`` — one
        :func:`model.mixed_loop` scan fusing the decode cohort's window
        with the joining rows' chunked suffix prefill, so a join or
        restore never stalls decode behind a separate dispatch.
        ``num_steps`` scan steps cover the longer tile (decode window vs
        ⌈suffix/chunk⌉ chunk steps); the shorter tile runs dead past its
        end.  Cached per (block_size, chunk, num_steps, donate)."""
        key = (block_size, chunk, num_steps, donate)
        fn = self._paged_mix_fns.get(key)
        if fn is not None:
            return fn
        if not self.supports_chunked:
            raise ValueError("mixed dispatch requires all-attention "
                             "windowless layers (see "
                             "model.supports_chunked_prefill)")
        cfg, ctx, capacity = self.cfg, self.ctx, self.capacity

        def mixed(params, pool, tok, pos, steps_left, tables,
                  stoks, spos, sn, stabs):
            return model.mixed_loop(
                cfg, params, pool, tok, pos, steps_left,
                stoks, spos, sn, ctx, block_tables=tables,
                sfx_tables=stabs, block_size=block_size, chunk=chunk,
                num_steps=num_steps, capacity=capacity)

        fn = jax.jit(mixed, donate_argnums=(1,) if donate else ())
        self._paged_mix_fns[key] = fn
        return fn

    def copy_fn(self, donate: bool = False):
        """Jitted copy-on-write: ``fn(pool, src (C,), dst (C,))`` copies
        the listed KV blocks on device across every pool leaf with a
        capacity axis (per-slot state rows pass through untouched) — one
        fused dispatch per CoW batch, see ``ops.copy_blocks``."""
        if self._copy_fns.get(donate) is None:
            from repro.kernels import ops as kops
            b_ax, c_ax = self._batch_axis, self._cap_axis

            def copy_into(pool, src, dst):
                def cp(leaf, bax, cax):
                    if cax is None:
                        return leaf
                    return kops.copy_blocks(leaf, src, dst, axis=bax)

                return jax.tree.map(cp, pool, b_ax, c_ax)

            self._copy_fns[donate] = jax.jit(
                copy_into, donate_argnums=(0,) if donate else ())
        return self._copy_fns[donate]

    def migrate_fn(self, compress: bool = False):
        """Jitted cross-pool KV migration (ADR-006): ``fn(dst_pool,
        src_pool, src_ids (C,), dst_ids (C,), src_slots (J,), dst_slots
        (J,))`` copies the listed KV blocks *between two pools* across
        every leaf with a capacity axis, and the listed per-slot
        recurrent-state rows across the leaves without one — the device
        half of moving a dying clone's in-flight requests to a survivor.
        Padding follows the serving conventions: block id 0 is the trash
        block on both sides (a 0→0 pad copy is a no-op) and an
        out-of-range destination slot drops its state-row write.

        ``compress=True`` is the compressed KV transfer of ADR-009: the
        gathered blocks round-trip through per-(block, head) int8
        quantization (``ops.quantize_kv_blocks``) before landing in the
        destination pool — the device realization of shipping the int8
        payload + scales over the inter-clone link, so decode on the
        receiving clone genuinely runs on dequantized KV."""
        fns = getattr(self, "_migrate_fns", None)
        if fns is None:
            fns = self._migrate_fns = {}
        if fns.get(compress) is None:
            from repro.kernels import ops as kops
            b_ax, c_ax = self._batch_axis, self._cap_axis

            def migrate(dst_pool, src_pool, src_ids, dst_ids,
                        src_slots, dst_slots):
                def mv(dleaf, sleaf, bax, cax):
                    if cax is None:          # per-slot state rows
                        d = jnp.moveaxis(dleaf, bax, 0)
                        s = jnp.moveaxis(sleaf, bax, 0)
                        return jnp.moveaxis(
                            d.at[dst_slots].set(s[src_slots], mode="drop"),
                            0, bax)
                    d = jnp.moveaxis(dleaf, bax, 0)
                    s = jnp.moveaxis(sleaf, bax, 0)
                    payload = s[src_ids]
                    if compress:
                        q, sc = kops.quantize_kv_blocks(payload)
                        payload = kops.dequantize_kv_blocks(
                            q, sc, dtype=dleaf.dtype)
                    return jnp.moveaxis(d.at[dst_ids].set(payload),
                                        0, bax)

                return jax.tree.map(mv, dst_pool, src_pool, b_ax, c_ax)

            fns[compress] = jax.jit(migrate)
        return fns[compress]


class ServingEngine:
    """Batched prefill + decode with ThinkAir placement decisions."""

    def __init__(self, cfg, *, policy: Policy = Policy.EXEC_TIME,
                 link: str = "wifi-local", max_batch: int = 8,
                 capacity: int = 256, backend: Optional[LMBackend] = None):
        self.cfg = cfg
        self.max_batch = max_batch
        self.capacity = capacity
        self.backend = backend or LMBackend(cfg, capacity)
        self.params = self.backend.params
        self.ec = ExecutionController(policy=policy, link=link)
        self.ec.pool.provision("main", 8)       # paused secondaries (paper)
        backend_ = self.backend

        # KV working set drives escalation: bytes ~ cache size
        def prefill_mem(params, tokens):
            return backend_.cache_mem_bytes(tokens.shape[0])

        self.rm_prefill = RemoteableMethod(
            "serve_prefill", self.backend.prefill, jit=False,
            size_fn=lambda p, t: t.size,
            split_fn=self._split_prefill, merge_fn=self._merge_prefill,
            mem_fn=prefill_mem)
        self.rm_decode = RemoteableMethod(
            "serve_decode", self.backend.decode, jit=False,
            size_fn=lambda p, c, t, pos: t.shape[0])
        self.stats = {"requests": 0, "batches": 0, "offloaded": 0,
                      "escalations": 0}

    @staticmethod
    def _split_prefill(args, k):
        params, tokens = args
        tok_shards = np.array_split(np.asarray(tokens), k, axis=0)
        return [(params, jnp.asarray(t)) for t in tok_shards]

    @staticmethod
    def _merge_prefill(values):
        toks = jnp.concatenate([v[0] for v in values], axis=0)
        caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                              *[v[1] for v in values])
        return toks, caches

    def serve_batch(self, reqs: List[Request], *, n_clones: int = 1,
                    force: Optional[str] = None) -> List[Completion]:
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
        res_p = self.ec.execute(self.rm_prefill, self.params,
                                jnp.asarray(toks), n_clones=n_clones,
                                force=force)
        next_tok, cache = res_p.value
        out = [list() for _ in reqs]
        steps_needed = max(r.max_new_tokens for r in reqs)
        tok = next_tok[:, None]
        total_time = res_p.time_s
        decode_venue = "-"
        # per-batch aggregation over prefill AND every decode step
        offloaded = int(res_p.offloaded)
        escalations = res_p.escalations
        for step_i in range(steps_needed):
            for i in range(len(reqs)):
                out[i].append(int(tok[i, 0]))
            pos = jnp.int32(min(plen + step_i, self.capacity - 1))
            res_d = self.ec.execute(self.rm_decode, self.params, cache, tok,
                                    pos, force=force)
            tok, cache = res_d.value
            tok = tok[:, None]
            total_time += res_d.time_s
            decode_venue = res_d.venue
            offloaded += int(res_d.offloaded)
            escalations += res_d.escalations
        self.stats["requests"] += len(reqs)
        self.stats["batches"] += 1
        self.stats["offloaded"] += offloaded
        self.stats["escalations"] += escalations
        return [Completion(r.rid, out[i], res_p.venue, decode_venue,
                           total_time, escalations)
                for i, r in enumerate(reqs)]


# --------------------------------------------------------------------------- #
# Event-driven Client Handler (continuous batching + elastic clones)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _Cohort:
    """Requests admitted at one step boundary, decoding in lockstep.

    This is the *contiguous* (legacy, ``kv="contiguous"``) batching unit:
    one shared position cursor, so only requests admitted at the same step
    boundary fuse, and late arrivals wait for a clone to free up.  The paged
    path (:class:`_SlotEngine`) replaces it as the default.
    """

    reqs: List[ServeRequest]
    clone: object
    plen: int
    outs: List[List[int]] = dataclasses.field(default_factory=list)
    first_token_t: List[float] = dataclasses.field(default_factory=list)
    token_ts: List[List[float]] = dataclasses.field(default_factory=list)
    cache: object = None
    tok: object = None
    step: int = 0
    phase: str = "prefill"


class PoolExhausted(RuntimeError):
    """Raised by the allocator when no block can be produced — the signal
    the serving layer converts into a preemption (ADR-003), never a
    crash."""


class KVBlockPool:
    """Host-side paged-KV bookkeeping for one engine (one clone).

    Owns the device block pool plus the block table, per-slot decode
    cursors, per-block *refcounts*, and a content-addressed **prefix
    index** (ADR-003).  Block id 0 is the *trash block*: it is never
    allocated, every inactive slot's table points at it, so decode writes
    from idle rows land somewhere harmless.

    Refcounted sharing: a block may appear in several slots' tables at
    once (``ref[b]`` = number of table references).  ``free_slot`` only
    decrements; a block returns to circulation at refcount zero — and if
    it is a *prompt* block recorded in the prefix index it parks on the
    ``cached-free`` list (still resident, LRU-evicted only when a fresh
    block is needed), so a later request with the same prompt prefix maps
    it back at refcount 1 instead of re-prefilling.

    The prefix index is a trie over full token blocks: node = physical
    block id, edge = that block's ``block_size`` token tuple under its
    parent (root = -1).  Admission walks the trie (``match_prefix``),
    maps every fully-matched block shared, and handles the *first
    divergent block* by copy-on-write: a cached block whose first ``rem``
    tokens match the remaining prompt is copied on device into a fresh
    private block (``ops.copy_blocks``) which the slot then overwrites
    from position ``cached_len`` on, leaving the shared source intact.

    Admission reserves only the *prompt's* private blocks (optimistic —
    no worst-case commitment); decode growth that exhausts the pool
    raises :class:`PoolExhausted`, which the engine resolves by
    preempting a victim slot instead of failing the request.
    """

    def __init__(self, backend, max_slots: int, block_size: int,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = True):
        self.backend = backend
        self.bs = block_size
        self.max_slots = max_slots
        self.capacity = backend.capacity
        self.max_blk = -(-backend.capacity // block_size)
        # default pool provisions worst case (+1 for the trash block);
        # benchmarks may size it tighter — preemption absorbs the squeeze
        self.num_blocks = num_blocks or max_slots * self.max_blk + 1
        self.pool = backend.init_paged_pool(max_slots, self.num_blocks,
                                            block_size)
        self.prefix_cache = prefix_cache
        self.tables = np.zeros((max_slots, self.max_blk), np.int32)
        self.pos = np.zeros((max_slots,), np.int32)
        self.active = np.zeros((max_slots,), bool)
        self.n_blocks_of = np.zeros((max_slots,), np.int32)
        self.ref = np.zeros((self.num_blocks,), np.int32)
        # bumped on every host-side table mutation; _SlotEngine caches the
        # device copy of ``tables`` against it (re-upload only when dirty)
        self.tables_version = 0
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self._free_blocks = list(range(self.num_blocks - 1, 0, -1))
        # prefix index: trie node = block id; root parent = -1
        self._children: Dict[int, Dict[tuple, int]] = {}
        self._node: Dict[int, tuple] = {}        # bid -> (parent, tokens)
        self._cached_free: Dict[int, None] = {}  # ref==0, indexed (LRU)
        # blocks whose cached content lands via an in-flight suffix scan:
        # not yet readable by a same-round sharer (see _submit_engine_step)
        self._pending: set = set()
        # cached-free blocks serving as CoW sources this round: eviction
        # must not recycle them before the device copy reads them
        self._hold: set = set()
        # trie nodes created by each slot's admission, until its prefill
        # completes: a *cancelled* admission must unindex exactly these
        # (their device content was never written)
        self._fresh_nodes: Dict[int, List[int]] = {}
        self.stats = {"hit_tokens": 0, "prompt_tokens": 0,
                      "cow_copies": 0, "evictions": 0}

    def reset(self) -> None:
        """Release every slot for engine reuse, *keeping the prefix
        index*: the device pool is retained as-is, so indexed blocks stay
        valid cached KV across engine generations on the same clone —
        that persistence is what lets serial (non-overlapping) requests
        still share a system prompt.  Stale content in unindexed blocks
        is harmless: prefill fully overwrites a slot's fresh blocks
        before any read and positions past a cursor are always masked."""
        for slot in range(self.max_slots):
            if self.n_blocks_of[slot]:
                self.free_slot(slot)
        self.pos[:] = 0
        self.active[:] = False
        self.tables_version += 1
        self._free_slots = list(range(self.max_slots - 1, -1, -1))
        self._pending.clear()
        self._hold.clear()

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def _need_blocks(self, prompt_len: int, max_new_tokens: int) -> int:
        total = min(prompt_len + max_new_tokens, self.capacity)
        return min(-(-max(total, prompt_len) // self.bs), self.max_blk)

    def available_blocks(self) -> int:
        """Blocks allocatable right now: free plus cached-but-unreferenced
        (the latter evict from the prefix index on demand)."""
        return len(self._free_blocks) + len(self._cached_free)

    # ------------------------------------------------------------ prefix
    def match_prefix(self, prompt: np.ndarray):
        """Longest cached prefix of ``prompt`` (pure — no state change).

        Returns ``(shared_ids, cow_src, cached_len)``: ``shared_ids`` are
        resident full blocks covering ``prompt[:len(shared_ids) * bs]``,
        ``cow_src`` is the first-divergent-block copy-on-write source (a
        cached block whose leading tokens extend the match partway), and
        ``cached_len`` the total matched token count.  The match is
        capped at ``len(prompt) - 1``: at least one suffix token is
        always re-prefilled, because the *logits* after the last prompt
        token (the row's first generated token) are not cached."""
        shared: List[int] = []
        cow_src = None
        c = 0
        if not self.prefix_cache:
            return shared, cow_src, 0
        p = len(prompt)
        parent = -1
        while c + self.bs <= p - 1:
            tok = tuple(int(t) for t in prompt[c:c + self.bs])
            b = self._children.get(parent, {}).get(tok)
            if b is None or b in self._pending:
                break
            shared.append(b)
            parent = b
            c += self.bs
        rem_cap = min(p - 1 - c, self.bs)
        if rem_cap > 0:
            want = tuple(int(t) for t in prompt[c:c + rem_cap])
            best_m = 0
            for tok, b in self._children.get(parent, {}).items():
                if b in self._pending:
                    continue
                m = 0
                while m < rem_cap and tok[m] == want[m]:
                    m += 1
                if m > best_m:      # ties: first-inserted child wins
                    best_m, cow_src = m, b
            c += best_m
        return shared, cow_src, c

    def _index_prompt(self, slot: int, prompt: np.ndarray,
                      n_shared: int, via_suffix: bool) -> None:
        """Record the slot's fully-covered prompt blocks as trie nodes.
        Blocks whose content arrives via an in-flight suffix scan are
        marked pending — unreadable by same-round sharers."""
        if not self.prefix_cache:
            return
        parent = -1
        created = self._fresh_nodes.setdefault(slot, [])
        for i in range(len(prompt) // self.bs):
            tok = tuple(int(t) for t in prompt[i * self.bs:
                                               (i + 1) * self.bs])
            kids = self._children.setdefault(parent, {})
            b = kids.get(tok)
            if b is None:
                b = int(self.tables[slot, i])
                kids[tok] = b
                self._node[b] = (parent, tok)
                created.append(b)
                if via_suffix and i >= n_shared:
                    self._pending.add(b)
            parent = b

    def clear_pending(self) -> None:
        """Called when a submitted step folds back: every suffix-written
        block's device content is now real (shareable), and in-flight CoW
        sources have been copied (evictable again)."""
        self._pending.clear()
        self._hold.clear()

    def _unindex(self, bid: int) -> None:
        """Drop ``bid`` from the trie.  Its cached descendants become
        unreachable (their chain is broken), so they are unindexed too
        and — when unreferenced — recycled straight to the free list."""
        parent, tok = self._node.pop(bid)
        kids = self._children.pop(bid, {})
        d = self._children.get(parent)
        if d is not None:
            d.pop(tok, None)
            if not d and parent != -1:
                del self._children[parent]
        self._pending.discard(bid)
        for child in kids.values():
            self._unindex(child)
            if self.ref[child] == 0 and child in self._cached_free:
                del self._cached_free[child]
                self._free_blocks.append(child)

    # ------------------------------------------------------- block alloc
    def _alloc_block(self) -> int:
        """A private block: free list first, then LRU-evict a cached-free
        block out of the prefix index; ``PoolExhausted`` when every block
        is referenced by a live slot."""
        evictable = (b for b in self._cached_free if b not in self._hold)
        if self._free_blocks:
            b = self._free_blocks.pop()
        elif (b := next(evictable, None)) is not None:  # LRU: oldest first
            del self._cached_free[b]
            self._unindex(b)
            self.stats["evictions"] += 1
        else:
            raise PoolExhausted(
                "KV block pool exhausted: all "
                f"{self.num_blocks - 1} blocks referenced by live slots "
                "(the engine preempts a victim when this surfaces "
                "mid-decode; a single request whose context exceeds the "
                "pool cannot be served — raise num_blocks)")
        self.ref[b] = 1
        return b

    def _ref_inc(self, bid: int) -> None:
        if self.ref[bid] == 0:
            self._cached_free.pop(bid, None)      # resurrected from cache
        self.ref[bid] += 1

    def can_admit(self, prompt, max_new_tokens: int = 0) -> bool:
        """True when a request's *prompt* fits now: a free slot plus
        enough allocatable blocks for its non-shared prompt blocks.
        ``prompt`` is the effective token array (prefix matching applies)
        or a bare length (no matching — the worst case).  Decode growth
        is not reserved: exhaustion mid-decode preempts a victim instead
        of being pre-gated, which is what keeps a tight pool admitting
        work instead of serializing on worst-case commitments."""
        if not self._free_slots:
            return False
        if isinstance(prompt, (int, np.integer)):
            p, n_shared, n_spoken_for = int(prompt), 0, len(self._hold)
        else:
            p = len(prompt)
            shared, cow_src, _ = self.match_prefix(prompt)
            n_shared = len(shared)
            # cached-free blocks this admission would *resurrect* or hold
            # as its CoW source (and already-held sources) can't also
            # serve the private need
            n_spoken_for = (sum(1 for b in shared if self.ref[b] == 0)
                            + sum(1 for b in self._hold
                                  if b in self._cached_free
                                  and b not in shared))
            if (cow_src is not None and self.ref[cow_src] == 0
                    and cow_src not in self._hold):
                n_spoken_for += 1
        nb0 = -(-p // self.bs)
        return self.available_blocks() - n_spoken_for >= nb0 - n_shared

    def used_blocks(self) -> int:
        """Blocks referenced by live slots (cached-free excluded: they
        are reclaimable, so they don't count against utilization)."""
        return int((self.ref[1:] > 0).sum())

    def written_tokens(self) -> int:
        """Logical tokens resident across slots (each slot counts its
        full context, so shared prefixes count once *per sharer* — the
        paged report divides this by physical reservation, and a ratio
        above 1.0 is exactly the prefix cache's memory win)."""
        return int(self.pos[self.active | (self.pos > 0)].sum())

    def alloc_slot(self, prompt, max_new_tokens: int = 0,
                   force_suffix: bool = False):
        """Claim a free slot for ``prompt`` (token array, or bare length
        to bypass prefix matching); cursor starts at the prompt length.

        Matches the prompt against the prefix index: fully-matched blocks
        enter the table shared (refcount + 1), the first divergent block
        is claimed as a fresh private block to be copied-on-write from
        ``cow_pair[0]``, and the remaining prompt blocks are fresh
        private allocations.  Returns ``(slot, new_ids, cached_len,
        cow_pair)``: ``new_ids`` are the blocks a *full* prefill must
        write (all of them when ``cached_len == 0``), ``cow_pair`` is
        ``(src, dst)`` or None.  ``force_suffix`` marks the row as
        suffix-prefilled regardless of match (restores), so its indexed
        blocks stay pending until the step folds."""
        if isinstance(prompt, (int, np.integer)):
            p = int(prompt)
            shared, cow_src, cached_len = [], None, 0
            indexable = False
        else:
            prompt = np.asarray(prompt, np.int32)
            p = len(prompt)
            shared, cow_src, cached_len = self.match_prefix(prompt)
            indexable = True
        slot = self._free_slots.pop()
        nb0 = -(-p // self.bs)
        for b in shared:
            self._ref_inc(b)
        cow_pair = None
        new_ids = []
        row = list(shared)
        if cow_src is not None:
            if self.ref[cow_src] == 0:
                # cached-free source: shield it from LRU eviction until
                # the round's device copy has read it (clear_pending)
                self._hold.add(cow_src)
            dst = self._alloc_block()
            cow_pair = (cow_src, dst)
            row.append(dst)
            self.stats["cow_copies"] += 1
        while len(row) < nb0:
            b = self._alloc_block()
            new_ids.append(b)
            row.append(b)
        self.tables[slot, :] = 0
        self.tables[slot, :nb0] = row
        self.pos[slot] = p
        self.n_blocks_of[slot] = nb0
        self.tables_version += 1
        if indexable:
            self._index_prompt(slot, prompt, len(shared),
                               via_suffix=force_suffix or cached_len > 0)
            self.stats["hit_tokens"] += cached_len
            self.stats["prompt_tokens"] += p
        return slot, np.asarray(new_ids, np.int32), cached_len, cow_pair

    def grow_for_window(self, counts) -> None:
        """Before a decode window: every active slot must own every block
        its next ``counts[slot]`` token writes land in (the window may
        cross several block boundaries, so the whole window's blocks are
        reserved up front — the scan cannot call back into the allocator
        mid-flight).  Write positions clamp at ``capacity - 1`` exactly
        like the decode path, so a window running past capacity needs no
        block beyond the last.  Raises :class:`PoolExhausted` when a
        block cannot be produced — the engine's preemption trigger; the
        call is resumable after a victim frees blocks (already-grown
        slots are skipped on re-entry)."""
        for slot in np.nonzero(self.active)[0]:
            n = int(counts[slot])
            if n <= 0:
                continue
            last = min(int(self.pos[slot]) + n - 1, self.capacity - 1)
            top = min(last // self.bs, self.max_blk - 1)
            while int(self.n_blocks_of[slot]) <= top:
                blk_i = int(self.n_blocks_of[slot])
                self.tables[slot, blk_i] = self._alloc_block()
                self.n_blocks_of[slot] = blk_i + 1
                self.tables_version += 1

    def grow_for_write(self) -> None:
        """One-token lookahead: the per-token decode path's pre-step grow."""
        self.grow_for_window(self.active.astype(np.int32))

    def free_slot(self, slot: int) -> None:
        """Retire (or preempt) a slot: decrement each referenced block,
        zero its table row (trash).  A block reaching refcount zero
        returns to the free list — or, when it is a prompt block in the
        prefix index, parks cached-free so the next same-prefix request
        restores it for free."""
        for j in range(int(self.n_blocks_of[slot])):
            b = int(self.tables[slot, j])
            self.ref[b] -= 1
            if self.ref[b] == 0:
                if b in self._node:
                    self._cached_free[b] = None       # LRU tail
                else:
                    self._free_blocks.append(b)
        self.tables[slot, :] = 0
        self.pos[slot] = 0
        self.active[slot] = False
        self.n_blocks_of[slot] = 0
        self.tables_version += 1
        self._fresh_nodes.pop(slot, None)   # prefill completed: nodes stay
        self._free_slots.append(slot)

    def cancel_slot(self, slot: int) -> None:
        """Undo an admission whose prefill never ran (join rollback):
        the trie nodes this admission created point at blocks whose
        device content was never written, so they must leave the index
        before the blocks recirculate — a later match against them would
        serve garbage KV.  Resurrected shared blocks (valid content from
        an earlier prefill) stay indexed and simply return cached-free."""
        for b in self._fresh_nodes.get(slot, ()):
            if b in self._node:             # may already be unindexed
                self._unindex(b)            # (recursion / LRU eviction)
        self.free_slot(slot)


@dataclasses.dataclass
class _Slot:
    """One request occupying one decode slot of a :class:`_SlotEngine`.

    ``token_ts`` mirrors ``out``: the streamed delivery timestamp of each
    emitted token (window folds interpolate within the dispatch interval
    — ADR-007 per-tenant TTFT/TPOT)."""

    req: ServeRequest
    out: List[int]
    first_token_t: float = 0.0
    token_ts: List[float] = dataclasses.field(default_factory=list)


def _carried_ts(req: ServeRequest, n: int) -> List[float]:
    """Delivery stamps carried across preempt/migrate/restore, clamped
    to ``n`` tokens and padded with the TTFT stamp when a legacy carrier
    did not record them."""
    ts = list(req.token_ts[:n])
    pad = req.first_token_t if req.first_token_t is not None else 0.0
    return ts + [pad] * (n - len(ts))


class _SlotEngine:
    """A clone's decode loop: max_batch slots over one paged KV pool.

    The engine replaces the cohort as the unit of batching.  A request is
    *admitted* into any free slot (``admit``) at any time — including while
    a decode step is in flight — and its prefill is folded into the next
    step, so late arrivals join mid-flight instead of waiting for the next
    cohort.  Slots retire independently at step granularity; their blocks
    return to the pool with no cache re-gather.
    """

    def __init__(self, backend, clone, kv: KVBlockPool, window: int = 1,
                 donate: bool = False, chunk: int = 0, mixed: bool = False):
        self.backend = backend
        self.clone = clone
        self.kv = kv
        self.window = window
        self.donate = donate
        # chunked suffix prefill: C tokens per scan step (0 = stepwise);
        # mixed: fold the suffix scan INTO the decode window's scan so a
        # join/restore never stalls the decode cohort (ADR-005)
        self.chunk = chunk
        self.mixed = mixed
        # decode_slots (the per-token fn) is deliberately unused here: the
        # engine always dispatches windows (window=1 == one-step window);
        # benchmarks/decode_micro.py is the per-token fn's only caller
        self.prefill_into, _, self.decode_window = \
            backend.paged_fns(kv.bs, window, donate)
        self.slots: List[Optional[_Slot]] = [None] * kv.max_slots
        self.tok_host = np.zeros((kv.max_slots,), np.int32)
        self.joins: List[tuple] = []        # (slot, req, toks, blk_ids)
        self.sfx_joins: List[tuple] = []    # (slot, req, sfx, pos0, restore)
        self.cow_pairs: List[tuple] = []    # (slot, src, dst) this round
        # inbound KV migrations from a dying clone (ADR-006):
        # (dst_slot, req, out, first_token_t, src_pool, src_ids, dst_ids,
        #  src_slot, pos) — folded into the next step as a device copy
        self.migrations: List[tuple] = []
        self.submitted_joins: List[tuple] = []
        self.submitted_sfx: List[tuple] = []
        self.submitted_migrations: List[tuple] = []
        self.decode_rows: Optional[np.ndarray] = None
        self.decode_counts: Optional[np.ndarray] = None
        self._tables_dev = None             # device tables cache
        self._tables_ver = -1
        # speculative decoding (ADR-008): the paired cheap-tier draft
        # clone, the draft model's own paged pool (SAME block tables as
        # ``kv``), per-slot draft-pool cursors (``dpos[i] <= kv.pos[i]``;
        # the gap is the committed history the next catch-up replays),
        # the stashed verify builder for the round in flight, and the
        # (drafts, n_spec) pending host-side acceptance.  ``spec_on``
        # goes (stickily) False when the draft dies or acceptance
        # collapses — the engine degrades to plain window decode.
        self.spec_on = False
        self.draft_clone = None
        self.draft_pool = None
        self.spec_k = 0
        self.dpos = np.zeros((kv.max_slots,), np.int32)
        self._verify_builder = None
        self._spec_round: Optional[np.ndarray] = None   # k per row, in flight
        self.spec_pending: Optional[tuple] = None
        self.spec_rounds_done = 0
        # disaggregated prefill (ADR-009): the paired large-tier prefill
        # clone and its scratch pool, rows awaiting the partner dispatch
        # (``disagg_joins``) or riding one (``submitted_disagg``), and the
        # decode-pool blocks still waiting for their streamed KV —
        # re-marked pending after every fold so no sharer attends over
        # them before the handoff copy lands.  ``disagg_on`` goes False
        # when the partner dies (degrade to co-located, never a stall).
        self.disagg_on = False
        self.prefill_clone = None
        self.prefill_pool: Optional[KVBlockPool] = None
        self.disagg_joins: List[tuple] = []       # (slot, req, eff, new_ids)
        self.submitted_disagg: List[tuple] = []
        self.disagg_blocks: Dict[int, List[int]] = {}
        # one main (step) task and at most one partner prefill task may
        # be in flight concurrently; the run loop pumps whichever side
        # has work and is idle
        self.main_inflight = False
        self.disagg_inflight = False

    def device_tables(self):
        """Device copy of ``kv.tables``, re-uploaded only when the host
        table has been dirtied since the last step (alloc/grow/free/reset
        all bump ``tables_version``)."""
        if self._tables_ver != self.kv.tables_version:
            self._tables_dev = jnp.asarray(self.kv.tables)
            self._tables_ver = self.kv.tables_version
        return self._tables_dev

    @staticmethod
    def effective_prompt(req: ServeRequest, prompt_pad: int,
                         capacity: int) -> np.ndarray:
        """The token sequence a slot's prefill must make resident.

        Fresh request: the prompt zero-padded to ``prompt_pad`` (padding
        tokens are context, exactly like the batched prefill path).  A
        preempted request restoring: padded prompt plus every generated
        token *except the last* — the last emitted token's KV was never
        written (it is the next decode input), so the restored cursor
        lands exactly where the preempted one stood.  The trailing
        ``[:capacity]`` clamp is a last resort for past-capacity victims
        (their last-cell overwrite history cannot be replayed anyway);
        ``_grow_or_preempt`` avoids choosing them while any in-capacity
        victim exists."""
        base = np.zeros((prompt_pad,), np.int32)
        base[:min(len(req.prompt), prompt_pad)] = req.prompt[:prompt_pad]
        if not req.generated:
            return base
        eff = np.concatenate(
            [base, np.asarray(req.generated[:-1], np.int32)])
        return eff[:capacity]

    def admit(self, req: ServeRequest, prompt_pad: int) -> dict:
        """Claim a slot + blocks; route the row to the batched full
        prefill (no cached prefix) or the suffix scan (prefix hit or
        preemption restore).  Returns admission stats for the handler."""
        restore = bool(req.generated)
        eff = self.effective_prompt(req, prompt_pad, self.kv.capacity)
        slot, new_ids, cached_len, cow = self.kv.alloc_slot(
            eff, req.max_new_tokens, force_suffix=restore)
        if cow is not None:
            self.cow_pairs.append((slot,) + cow)
        if restore or cached_len > 0:
            sfx = eff[cached_len:]
            self.sfx_joins.append((slot, req, sfx, cached_len, restore))
            return {"cached": cached_len, "suffix": len(sfx),
                    "restore": restore, "prompt": len(eff)}
        self.joins.append((slot, req, jnp.asarray(eff[None]),
                           jnp.asarray(new_ids)))
        return {"cached": 0, "suffix": 0, "restore": False,
                "prompt": len(eff)}

    def alive(self) -> bool:
        return (any(s is not None for s in self.slots)
                or bool(self.joins) or bool(self.sfx_joins)
                or bool(self.migrations) or bool(self.disagg_joins)
                or bool(self.submitted_disagg))

    def step_work(self) -> bool:
        """Does the engine have anything for its *own* clone to run right
        now?  Rows parked on the disagg partner are excluded — an alive
        engine with only those in flight waits for the handoff instead of
        dispatching an empty step."""
        return (bool(self.kv.active.any()) or bool(self.joins)
                or bool(self.sfx_joins) or bool(self.migrations))


@dataclasses.dataclass
class ServeReport:
    """One ``ClientHandler.run`` outcome: latency, elasticity, KV economy.

    ``kv_util`` is the time-averaged fraction of *reserved* KV memory that
    holds written tokens (sampled at every decode submission); contiguous
    cohorts reserve ``rows x capacity`` up front while the paged pool only
    reserves allocated blocks.  With prefix sharing every sharer counts
    its full logical context, so ``kv_util`` above 1.0 means shared
    blocks are serving more logical tokens than their physical size — the
    prefix cache's memory win.  ``kv_reserved_peak`` is the peak physical
    reservation in tokens.  ``prefix_hit_rate`` is cached prompt tokens /
    total prompt tokens over all admissions (restores included);
    ``preemptions`` counts slot evictions under pool pressure and
    ``restored_tokens`` the tokens re-prefilled bringing victims back.

    Fleet economics (ADR-004): ``fleet_mix`` counts completions per clone
    type, ``escalations`` the requests whose KV demand forced a bigger
    tier, ``clone_seconds_by_type`` the RUNNING clone-seconds billed per
    tier (idle-but-running time included — that is what TTL pausing
    saves), ``cost_usd`` their on-demand $ total, ``energy_j_by_type``
    the chips-aware busy energy per tier, and ``power_offs`` the clones
    the OFF_IDLE_TTL actually powered off.
    """

    completions: List[ServeCompletion]
    accepted: int
    rejected: int
    makespan_s: float
    p50_latency_s: float
    p99_latency_s: float
    p50_ttft_s: float
    tokens_per_s: float
    peak_secondaries: int
    scale_ups: int
    busy_energy_j: float
    pool_stats: Dict
    clone_samples: List[tuple]
    kv_mode: str = "paged"
    kv_util: float = 0.0
    kv_reserved_peak: int = 0
    prefix_hit_rate: float = 0.0
    preemptions: int = 0
    restored_tokens: int = 0
    fleet_mix: Dict[str, int] = dataclasses.field(default_factory=dict)
    escalations: int = 0
    clone_seconds_by_type: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    cost_usd: float = 0.0
    energy_j_by_type: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    power_offs: int = 0
    # fault tolerance (ADR-006): ``faults_injected`` counts fired clone
    # faults (kills + drains + slowdowns), ``recoveries_migrated`` the
    # in-flight requests whose KV blocks moved to a survivor's pool,
    # ``recoveries_restored`` those requeued for prefix-accelerated
    # re-prefill, ``hedges_fired``/``hedge_wins`` the straggler decode
    # windows raced on a second clone and the races the duplicate won,
    # ``breaker_opens`` the fleet-wide circuit-breaker open transitions
    faults_injected: int = 0
    recoveries_migrated: int = 0
    recoveries_restored: int = 0
    hedges_fired: int = 0
    hedge_wins: int = 0
    breaker_opens: int = 0
    # gateway SLO telemetry (ADR-007): ``slo_attainment`` maps SLO class
    # -> fraction of *offered* requests in that class that were served
    # inside their deadline (no-deadline completions count as met;
    # gateway-rejected/shed/dropped work counts as missed — honesty under
    # overload), ``goodput_tps`` counts only deadline-meeting delivered
    # tokens per second (cache hits included: they are real deliveries),
    # ``gateway_shed``/``gateway_rejected`` the bounded-backlog evictions
    # and predictive up-front rejections, ``gateway_retries`` scheduled
    # Retry-After replays, ``cache_hits`` responses served from the
    # gateway's exact-match LRU, ``shed_by_slo`` sheds per class (must
    # never contain "interactive"), ``per_tenant`` served/p50 TTFT/p50
    # TPOT per tenant, ``peak_queue_depth`` the deepest handler admission
    # queue observed (the divergence metric for ungated overload)
    slo_attainment: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    goodput_tps: float = 0.0
    gateway_shed: int = 0
    gateway_rejected: int = 0
    gateway_retries: int = 0
    cache_hits: int = 0
    shed_by_slo: Dict[str, int] = dataclasses.field(default_factory=dict)
    per_tenant: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    peak_queue_depth: int = 0
    # speculative decoding (ADR-008): ``spec_rounds`` counts completed
    # draft+verify rounds, ``spec_tokens`` the tokens emitted through
    # them (lossless: token-identical to plain greedy decode),
    # ``acceptance_rate`` accepted / proposed draft tokens, and
    # ``spec_fallbacks`` the engines that degraded to plain decode —
    # draft-clone death or acceptance collapse, never a stall
    spec_rounds: int = 0
    spec_tokens: int = 0
    acceptance_rate: float = 0.0
    spec_fallbacks: int = 0
    # disaggregated prefill/decode (ADR-009): ``disagg_handoffs`` counts
    # prompts prefilled on the partner tier whose KV blocks migrated to
    # the decode clone, ``disagg_colocated`` the long-prompt candidates
    # the transfer-cost planner kept local, ``disagg_fallbacks`` the
    # engines that degraded to co-located prefill (no partner available
    # or partner death), ``kv_transfer_bytes``/``kv_transfer_s`` the
    # modeled cross-clone KV handoff traffic (compressed transfers bill
    # the int8 payload + scales), and ``per_clone`` the per-clone routing
    # telemetry: prefix hit rate and KV transfer volume per clone id.
    disagg_handoffs: int = 0
    disagg_colocated: int = 0
    disagg_fallbacks: int = 0
    kv_transfer_bytes: float = 0.0
    kv_transfer_s: float = 0.0
    per_clone: Dict[str, Dict[str, object]] = dataclasses.field(
        default_factory=dict)

    def summary(self) -> str:
        """One-line digest (documented in docs/benchmarks.md)."""
        return (f"served={len(self.completions)} shed={self.rejected} "
                f"p50={self.p50_latency_s:.3f}s p99={self.p99_latency_s:.3f}s "
                f"ttft50={self.p50_ttft_s:.3f}s "
                f"tok/s={self.tokens_per_s:.1f} "
                f"kv={self.kv_mode} kv_util={self.kv_util:.0%} "
                f"prefix_hits={self.prefix_hit_rate:.0%} "
                f"preempt={self.preemptions} "
                f"peak_secondaries={self.peak_secondaries}")


class ClientHandler:
    """Event-driven continuous-batching server on an elastic clone pool.

    ``kv="paged"`` (default): each clone runs a :class:`_SlotEngine` —
    ``max_batch`` slots over a :class:`KVBlockPool`, per-slot decode
    cursors, mid-flight admission into free slots.  ``kv="contiguous"``
    keeps the PR-1 cohort path (shared cursor, step-boundary fusion only)
    as the measurable baseline for the paged design.
    """

    def __init__(self, backend, *, link: str = "wifi-local",
                 clone_type: str = "main", max_batch: int = 4,
                 queue_depth: int = 64, max_secondaries: int = 8,
                 min_secondaries: int = 0, work_per_clone: int = 1,
                 prompt_pad: int = 8, use_primary: bool = True,
                 provision_paused: bool = True,
                 kv: str = "paged", block_size: int = 8,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 decode_window: int = 1, donate_kv: bool = False,
                 prefill_chunk: Optional[int] = None,
                 mixed_dispatch: Optional[bool] = None,
                 fleet: Optional[List[str]] = None,
                 placement_policy: Policy = Policy.EXEC_TIME_AND_ENERGY,
                 energy_model: Optional[TpuEnergyModel] = None,
                 provision: Optional[Dict[str, int]] = None,
                 executor: Optional[Callable] = None,
                 pool: Optional[ClonePool] = None,
                 clock: Optional[VirtualClock] = None,
                 faults: Optional[List[CloneFault]] = None,
                 hedge_factor: float = 0.0,
                 hedge_quantile: float = 0.95,
                 hedge_min_samples: int = 8,
                 gateway: Optional[StreamingGateway] = None,
                 breaker_max_open_s: Optional[float] = None,
                 breaker_max_probes: Optional[int] = None,
                 speculative: bool = False, spec_k: int = 4,
                 spec_corruption: float = 0.0,
                 draft_cost: Optional[float] = None,
                 routing: str = "ledger",
                 disagg: bool = False, disagg_link: str = "ici",
                 disagg_compress: bool = False,
                 disagg_min_prompt: Optional[int] = None,
                 disagg_prefill_type: Optional[str] = None):
        if kv not in ("paged", "contiguous"):
            raise ValueError(f"kv must be 'paged' or 'contiguous': {kv!r}")
        if faults and kv != "paged":
            raise ValueError("fault injection requires kv='paged' — the "
                             "contiguous cohort keeps no per-slot restore "
                             "state, so a clone death would lose tokens")
        if hedge_factor > 0 and kv != "paged":
            raise ValueError("hedged dispatch races _SlotEngine decode "
                             "windows; it requires kv='paged'")
        if hedge_factor > 0 and donate_kv:
            raise ValueError("hedged dispatch re-runs the step closure on "
                             "a second clone; a donated KV pool is "
                             "consumed by the first run (ADR-002)")
        if decode_window < 1:
            raise ValueError(f"decode_window must be >= 1: {decode_window}")
        if decode_window > 1 and kv != "paged":
            raise ValueError("decode_window > 1 requires kv='paged' (the "
                             "contiguous cohort path decodes per token)")
        if donate_kv and executor is None:
            # the default Venue executor re-runs a closure to stabilize its
            # timing; a donated pool is consumed by the first run
            raise ValueError("donate_kv needs an executor that runs each "
                             "dispatch exactly once (the default venue "
                             "executor re-times cheap calls)")
        # cross-tier speculative decoding (ADR-008)
        if speculative:
            if kv != "paged":
                raise ValueError("speculative decoding scores draft "
                                 "windows through per-slot block tables; "
                                 "it requires kv='paged'")
            if donate_kv:
                raise ValueError("speculative decoding keeps the target "
                                 "pool alive across the draft round-trip; "
                                 "a donated pool is consumed (ADR-002)")
            if not getattr(backend, "supports_speculative", False):
                raise ValueError("speculative decoding needs a backend "
                                 "with a bound draft model "
                                 "(LMBackend(draft=...)) and chunked-"
                                 "verify support")
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1: {spec_k}")
        # prefix-affinity / random routing (ADR-009): "ledger" keeps the
        # pure free-slot policy; "affinity" scores candidate engines by
        # prefix-index match depth on the incoming prompt; "random" is the
        # affinity sweep's control arm
        if routing not in ("ledger", "affinity", "random"):
            raise ValueError("routing must be 'ledger', 'affinity' or "
                             f"'random': {routing!r}")
        if routing != "ledger" and kv != "paged":
            raise ValueError("prefix-affinity/random routing scores the "
                             "paged prefix index; it requires kv='paged'")
        self.routing = routing
        # disaggregated prefill/decode (ADR-009)
        if disagg:
            if kv != "paged":
                raise ValueError("disaggregated prefill migrates paged KV "
                                 "blocks between clones; it requires "
                                 "kv='paged'")
            if not getattr(backend, "supports_chunked", False):
                raise ValueError("disaggregated prefill replays prompts "
                                 "through the chunked paged-prefill scan; "
                                 "the backend must support chunked prefill "
                                 "(all-attention, windowless layers)")
            if speculative:
                raise ValueError("disaggregated prefill and speculative "
                                 "decoding both pair the engine with a "
                                 "partner clone; run one at a time")
            if donate_kv:
                raise ValueError("disaggregated prefill keeps the partner "
                                 "pool alive across the handoff; a donated "
                                 "pool is consumed (ADR-002)")
            if disagg_link not in LINKS:
                raise ValueError(f"unknown disagg_link {disagg_link!r}; "
                                 f"known: {sorted(LINKS)}")
        self.disagg = disagg
        self.disagg_link = disagg_link
        self.disagg_compress = disagg_compress
        self.disagg_min_prompt = disagg_min_prompt
        self.speculative = speculative
        self.spec_k = spec_k
        self.spec_corruption = spec_corruption
        self.draft_cost = (draft_cost if draft_cost is not None
                           else getattr(backend, "draft_cost_ratio", 1.0))
        # chunked prefill / mixed dispatch (ADR-005): default ON whenever
        # the backend supports it (all-attention, windowless) and the KV
        # mode is paged; backends without the capability flag (test stubs)
        # keep the legacy stepwise path
        chunk_ok = kv == "paged" and bool(getattr(backend,
                                                  "supports_chunked", False))
        if prefill_chunk is None:
            prefill_chunk = 8 if chunk_ok else 0
        elif prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0: {prefill_chunk}")
        elif prefill_chunk > 0 and not chunk_ok:
            raise ValueError("prefill_chunk > 0 requires kv='paged' and a "
                             "backend with chunked-prefill support "
                             "(all-attention, windowless layers)")
        if mixed_dispatch is None:
            # speculative engines keep prefill and verify as separate
            # tiles of one closure (the verify window IS the decode
            # tile); the fused mixed scan has no verify variant
            mixed_dispatch = prefill_chunk > 0 and not speculative
        elif mixed_dispatch and speculative:
            raise ValueError("mixed_dispatch and speculative decoding are "
                             "mutually exclusive: the spec round's decode "
                             "tile is a verify window, not a decode scan")
        elif mixed_dispatch and prefill_chunk == 0:
            raise ValueError("mixed_dispatch requires prefill_chunk > 0 "
                             "(the fused step advances chunk tokens per "
                             "scan step)")
        self.prefill_chunk = prefill_chunk
        self.mixed_dispatch = mixed_dispatch
        self.kv_mode = kv
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.prefix_cache = prefix_cache
        self.decode_window = decode_window
        self.donate_kv = donate_kv
        self.backend = backend
        # breaker tuning (satellite of ADR-007): custom cooldown cap /
        # probe-chain cap for every clone the pool creates
        breaker_kwargs = {}
        if breaker_max_open_s is not None:
            breaker_kwargs["max_open_seconds"] = breaker_max_open_s
        if breaker_max_probes is not None:
            breaker_kwargs["max_probes"] = breaker_max_probes
        # one timeline: adopt a supplied pool's clock (TTL accounting and
        # dispatch must share it), otherwise build pool around ours
        if pool is not None:
            if not getattr(pool.clock, "virtual", False):
                raise TypeError("ClientHandler needs a pool on a "
                                "VirtualClock")
            if clock is not None and clock is not pool.clock:
                raise ValueError("pool and clock disagree — pass one "
                                 "timeline")
            self.clock = pool.clock
            self.pool = pool
            if breaker_kwargs:
                self.pool.breaker_kwargs.update(breaker_kwargs)
                for c in self.pool.clones:     # retrofit existing clones
                    c.breaker = CircuitBreaker(**self.pool.breaker_kwargs)
        else:
            self.clock = clock or VirtualClock()
            self.pool = ClonePool(link_name=link, clock=self.clock,
                                  max_clones=max_secondaries + 8,
                                  breaker_kwargs=breaker_kwargs)
        self.dispatcher = Dispatcher(self.pool, self.clock)
        self.queue = AdmissionQueue(queue_depth)
        # heterogeneous fleet (ADR-004): allowed tiers, rank-ascending;
        # the base clone_type is always a member, so fleet=None keeps the
        # exact homogeneous behaviour
        names = set(fleet or []) | {clone_type}
        self.fleet = sorted(names, key=lambda n: CLONE_TYPES[n].rank())
        self._fleet_set = set(self.fleet)
        self.energy = energy_model or TpuEnergyModel()
        self.placement = PlacementEngine(self.pool, fleet=self.fleet,
                                         policy=placement_policy,
                                         energy=self.energy)
        self.autoscaler = FleetAutoscaler(
            self.pool, self.placement, base_type=clone_type,
            work_per_clone=work_per_clone,
            min_secondaries=min_secondaries, max_secondaries=max_secondaries)
        if provision_paused:     # paper §5.3: secondaries pre-created paused
            self.pool.provision(clone_type, max_secondaries)
        for tname, n in (provision or {}).items():   # extra paused tiers
            self.pool.provision(tname, n)
        self.clone_type = clone_type
        self.max_batch = max_batch
        self.prompt_pad = prompt_pad
        self.use_primary = use_primary
        if not use_primary and max_secondaries < 1:
            raise ValueError("no primary and no secondaries: nothing can run")
        # executor(clone, fn, args) -> (value, venue_seconds); the default
        # runs on the clone's venue spec (tests inject fixed venue times)
        if executor is None:
            def executor(clone, fn, args):
                return Venue(clone.spec).execute(fn, *args)
        self.executor = executor
        self.busy_energy_j = 0.0
        self.tokens_emitted = 0
        self.ledger = SlotLedger()
        self.kv_samples: List[tuple] = []   # (written_tokens, reserved)
        self._kv_pools: Dict[int, KVBlockPool] = {}   # clone.cid -> pool
        # prefix-cache / preemption economics (ADR-003)
        self.preemptions = 0
        self.restored_tokens = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens = 0
        # fleet economics (ADR-004)
        self.energy_j_by_type: Dict[str, float] = {}
        self.busy_seconds_by_type: Dict[str, float] = {}
        self.fleet_mix: Dict[str, int] = {}        # completions per type
        self._escalated: set = set()               # rids forced up a tier
        # peak queued demand per (tenant, priority, required tier) class
        self.demand_by_class: Dict[tuple, int] = {}
        # rid -> (lo, hi) placement band, valid for one scheduler round
        # (invalidated whenever pool inventory changes — engine spawns)
        self._band_cache: Dict[int, tuple] = {}
        # SLO-aware gateway (ADR-007): arrivals flow through it when
        # present; it shares the serving timeline and link profile
        self.gateway = gateway
        if gateway is not None:
            gateway.adopt_clock(self.clock)
        # fault tolerance + hedging (ADR-006); a gateway hears about
        # kills/drains at the fault instant (capacity-loss signal)
        self.injector = (FaultInjector(
            self.pool, faults,
            on_fire=(gateway.note_fault if gateway is not None else None))
            if faults else None)
        self._peak_queue_depth = 0
        self.hedge_factor = hedge_factor
        self.hedge_quantile = hedge_quantile
        self.hedge_min_samples = hedge_min_samples
        self.recoveries_migrated = 0
        self.recoveries_restored = 0
        self.hedges_fired = 0
        self.hedge_wins = 0
        self._hedges: Dict[object, object] = {}   # task <-> partner
        self._step_hist: List[float] = []         # recent step durations
        self._kv_tok_bytes: Optional[float] = None
        # speculative telemetry (ADR-008); ``spec_draft_cids`` records
        # every clone ever paired as a draft (fault tests target them),
        # ``_spec_rng`` drives the deterministic bench-harness corruption
        self.spec_rounds = 0
        self.spec_tokens = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_fallbacks = 0
        self.spec_draft_cids: List[int] = []
        self._spec_rng = np.random.default_rng(0xC0FFEE)
        # disaggregated prefill + routing state (ADR-009): the partner
        # tier defaults to the top of the fleet ladder (prefill is
        # compute-bound — the fastest tier amortizes best).  ONE partner
        # clone is shared, refcounted, by every disagg engine — that
        # sharing is the $-economics of the whole design: k cheap decode
        # engines amortize a single premium prefill clone.  Each engine
        # still owns a private partner-side scratch pool (keyed by its
        # *decode* clone, reused across engine generations), so
        # overlapping partner dispatches never clobber device state.
        # The seeded routing rng keeps the "random" arm deterministic.
        if disagg_prefill_type is not None \
                and disagg_prefill_type not in CLONE_TYPES:
            raise ValueError(f"unknown disagg_prefill_type "
                             f"{disagg_prefill_type!r}")
        self.disagg_prefill_type = disagg_prefill_type or self.fleet[-1]
        self._prefill_pools: Dict[int, KVBlockPool] = {}
        self._partner_clone = None
        self._partner_refs = 0
        self._route_rng = np.random.default_rng(0xD15A66)
        self.disagg_handoffs = 0
        self.disagg_colocated = 0
        self.disagg_fallbacks = 0
        self.kv_transfer_bytes = 0.0
        self.kv_transfer_s = 0.0
        self.per_clone_stats: Dict[int, Dict[str, object]] = {}
        self._disagg_blk_bytes: Optional[int] = None
        self._n_params: Optional[int] = None

    # ---------------------------------------------------------------- clones
    def _free_clone(self, lo_rank: Optional[int] = None,
                    hi_rank: Optional[int] = None,
                    prefer_cid: Optional[int] = None):
        """Best usable clone inside the ``[lo_rank, hi_rank]`` band:
        soonest-ready first (a free clone must never lose to one still
        booting), then the smallest tier, then cid.  Cost discipline
        lives in the band itself — a request's ``hi`` is the tier the
        placement policy chose for it, so a dearer tier is simply not a
        candidate.  The primary is exempt from the band's *upper* bound:
        it is standing capacity billed whether or not it serves, so using
        it can never squat paid-for premium.

        ``prefer_cid`` (ADR-009 affinity routing) wins among candidates
        tied on readiness — a prefix-warm clone beats tier order, but a
        free clone still never loses to one that is booting.  Under
        ``routing="random"`` the pick is uniform over the candidate set
        (the affinity sweep's control arm)."""
        def in_band(rank, primary=False):
            return ((lo_rank is None or rank >= lo_rank)
                    and (primary or hi_rank is None or rank <= hi_rank))

        now = self.clock.now()
        cands = []
        p = self.pool.primary
        # dead / open-breaker clones never take new work (ADR-006): a
        # tripped breaker re-closes only through its half-open probe
        if self.use_primary and not p.busy and p.serveable \
                and in_band(p.ctype.rank(), primary=True):
            cands.append((0.0, p.ctype.rank(), 0, p))
        for c in self.pool.running_secondaries():
            if c.busy or not c.serveable \
                    or c.ctype.name not in self._fleet_set:
                continue
            if not in_band(c.ctype.rank()):
                continue
            cands.append((self.autoscaler.clone_ready_delay(c, now),
                          c.ctype.rank(), c.cid, c))
        if not cands:
            return None
        if self.routing == "random":
            return cands[int(self._route_rng.integers(len(cands)))][3]
        best = min(cands)
        if prefer_cid is not None:
            for c in cands:
                if c[3].cid == prefer_cid and c[0] <= best[0] + 1e-12:
                    return c[3]
        return best[3]

    def _net_s(self, nbytes: int) -> float:
        return transfer_time(nbytes, self.pool.link)

    def _kv_token_bytes(self) -> float:
        """Bytes of KV state one context token occupies — what a block
        migration or a hedge's context transfer moves per token.  Derived
        from the backend's own cache accounting when it has one (test
        stubs fall back to a small constant)."""
        if self._kv_tok_bytes is None:
            fn = getattr(self.backend, "cache_mem_bytes", None)
            if fn is not None:
                self._kv_tok_bytes = float(fn(1)) / self.backend.capacity
            else:
                self._kv_tok_bytes = 64.0
        return self._kv_tok_bytes

    # ------------------------------------------- disagg / affinity (ADR-009)
    def _clone_stat(self, clone) -> Dict[str, object]:
        """Per-clone routing telemetry bucket (ServeReport.per_clone)."""
        st = self.per_clone_stats.get(clone.cid)
        if st is None:
            st = self.per_clone_stats[clone.cid] = {
                "type": clone.ctype.name, "prefix_hit_tokens": 0,
                "prompt_tokens": 0, "kv_transfer_bytes": 0.0,
                "kv_transfer_s": 0.0}
        return st

    def _disagg_block_bytes(self) -> int:
        """Modeled wire bytes of one KV block on the handoff link —
        ``venues.kv_block_bytes`` when the backend carries a real model
        config (int8 payload + per-head scales when compressing), else a
        backend-derived fallback (test stubs)."""
        if self._disagg_blk_bytes is None:
            cfg = getattr(self.backend, "cfg", None)
            if cfg is not None and hasattr(cfg, "layer_kinds"):
                self._disagg_blk_bytes = kv_block_bytes(
                    cfg, self.block_size, quantized=self.disagg_compress)
            else:
                raw = self._kv_token_bytes() * self.block_size
                self._disagg_blk_bytes = int(
                    raw / 4 if self.disagg_compress else raw)
        return self._disagg_blk_bytes

    def _param_count(self) -> Optional[int]:
        """Backend parameter count (prefill FLOPs model); None for stub
        backends whose params aren't an array pytree."""
        if self._n_params is None:
            try:
                self._n_params = sum(
                    int(np.prod(x.shape))
                    for x in jax.tree.leaves(self.backend.params))
            except Exception:
                self._n_params = -1
        return None if self._n_params < 0 else self._n_params

    def _disagg_worth(self, engine: "_SlotEngine", plen: int) -> bool:
        """Per-request disagg-vs-co-located planner: ship the prefill to
        the partner tier only when the modeled prefill-time gain (prompt
        FLOPs at the decode tier vs the partner tier) exceeds the KV
        handoff's wire cost on ``disagg_link``.  An explicit
        ``disagg_min_prompt`` replaces the model with a plain length
        threshold (and is the stub-backend fallback)."""
        if self.disagg_min_prompt is not None:
            return plen >= self.disagg_min_prompt
        pc = self._param_count()
        if pc is None:
            return True
        flops = 2.0 * pc * plen
        gain = (flops / engine.clone.spec.eff_flops
                - flops / engine.prefill_clone.spec.eff_flops)
        nb = -(-plen // self.block_size)
        wire = transfer_time(nb * self._disagg_block_bytes(),
                             LINKS[self.disagg_link])
        return gain > wire

    def _affinity_depth(self, kvp: KVBlockPool, req: ServeRequest) -> int:
        """Cached-prefix depth (tokens) this pool holds for ``req`` — the
        affinity routing score; pure (``match_prefix`` mutates nothing)."""
        eff = _SlotEngine.effective_prompt(req, self.prompt_pad,
                                           self.backend.capacity)
        return int(kvp.match_prefix(eff)[2])

    def _affinity_by_type(self, req: ServeRequest) -> Dict[str, int]:
        """Per-tier best prefix-match depth over live clone pools — the
        ``prefix_affinity`` hint's input to PlacementEngine.choose_type."""
        out: Dict[str, int] = {}
        by_cid = {c.cid: c for c in self.pool.clones}
        for cid, kvp in self._kv_pools.items():
            clone = by_cid.get(cid)
            if clone is None or not clone.serveable:
                continue
            d = self._affinity_depth(kvp, req)
            t = clone.ctype.name
            out[t] = max(out.get(t, 0), d)
        return out

    def _best_affinity_cid(self, req: ServeRequest) -> Optional[int]:
        """Clone id with the deepest cached prefix for ``req`` (spawn-
        time affinity: engine pools persist across generations on the
        same clone, so routing the spawn there revives its index)."""
        best, best_d = None, 0
        for cid, kvp in sorted(self._kv_pools.items()):
            d = self._affinity_depth(kvp, req)
            if d > best_d:
                best, best_d = cid, d
        return best

    # ------------------------------------------------------------- placement
    def _charge(self, clone, venue_seconds: float) -> None:
        """Bill one dispatch's busy energy, chips-aware (ADR-004): the
        venue's chip count scales the bill through the TPU energy model
        instead of the old flat ``venue_seconds x power_peak``."""
        e = self.energy.busy_j(chips=clone.spec.chips, seconds=venue_seconds)
        self.busy_energy_j += e
        t = clone.ctype.name
        self.energy_j_by_type[t] = self.energy_j_by_type.get(t, 0.0) + e
        self.busy_seconds_by_type[t] = (
            self.busy_seconds_by_type.get(t, 0.0) + venue_seconds)

    def _blocks_for_type(self, type_name: str) -> int:
        """KV block-pool size for an engine on this clone type: the base
        tier gets exactly ``num_blocks``, bigger tiers scale with the
        fleet memory ladder (``KV_SCALE_BY_CLONE_TYPE``), all capped at
        the worst case every slot could ever write."""
        max_blk = -(-self.backend.capacity // self.block_size)
        worst = self.max_batch * max_blk + 1
        if self.num_blocks is None:
            return worst
        if len(self.fleet) == 1:     # homogeneous: exact pre-fleet sizing
            return self.num_blocks
        scale = (KV_SCALE_BY_CLONE_TYPE[type_name]
                 / KV_SCALE_BY_CLONE_TYPE[self.clone_type])
        return min(worst, max(2, int(self.num_blocks * scale)))

    def _request_blocks(self, req: ServeRequest) -> int:
        """Worst-case KV blocks this request's slot can come to hold
        (prompt+window demand — mirrors ``KVBlockPool._need_blocks`` over
        the effective restore-aware prompt length)."""
        p = self.prompt_pad
        if req.generated:
            p = min(p + len(req.generated) - 1, self.backend.capacity)
        total = min(p + req.max_new_tokens, self.backend.capacity)
        max_blk = -(-self.backend.capacity // self.block_size)
        return min(-(-max(total, p) // self.block_size), max_blk)

    def _required_type(self, req: ServeRequest) -> str:
        """The smallest fleet tier whose block pool holds this request —
        the live admission analogue of the paper's OutOfMemoryError ->
        bigger-VM escalation.  Homogeneous fleets (and the contiguous
        cohort path, which has no block pool) short-circuit to the base
        type; a request no tier can hold degrades to the top tier, where
        preemption absorbs the squeeze."""
        if len(self.fleet) == 1 or self.kv_mode != "paged":
            return self.clone_type
        t = self.placement.required_type(
            self.clone_type, self._request_blocks(req),
            lambda n: self._blocks_for_type(n) - 1)   # -1: trash block
        if t != self.clone_type:
            self._escalated.add(req.rid)
        return t

    def _placement_band(self, req: ServeRequest) -> tuple:
        """Rank band ``(lo, hi)`` of clone types this request may run on.

        ``lo`` is the escalation floor (KV demand); ``hi`` the tier the
        placement engine would provision for it *now* — so bulk does not
        squat on premium engines' free slots just because their rank is
        adequate, and the fleet's $-policy governs joins as well as
        spawns.  Urgent requests rank by latency, so their band widens to
        whatever tier is warm.  Homogeneous fleets are unconstrained
        (``(None, None)`` — exact pre-fleet behaviour: the single
        secondary type plus the always-on primary).  Bands are cached per
        scheduler round (``_band_cache``) — they depend only on the
        request and pool inventory, not on which engine asks."""
        cached = self._band_cache.get(req.rid)
        if cached is not None:
            return cached
        if len(self.fleet) == 1:
            band = (None, None)
        else:
            rt = self._required_type(req)
            lo = CLONE_TYPES[rt].rank()
            hints = {}
            if self.routing == "affinity":
                # prefix-affinity placement (ADR-009): a tier holding the
                # request's cached prefix outranks the $-policy order
                hints = {"hint": "prefix_affinity",
                         "affinity": self._affinity_by_type(req)}
            ct = self.placement.choose_type(rt, urgent=req.priority > 0,
                                            **hints) or rt
            band = (lo, max(lo, CLONE_TYPES[ct].rank()))
        self._band_cache[req.rid] = band
        return band

    def _in_band(self, req: ServeRequest, clone) -> bool:
        """Is ``clone`` inside the request's placement band?  The primary
        is exempt from the upper bound (standing capacity — see
        ``_free_clone``)."""
        lo, hi = self._placement_band(req)
        rank = clone.ctype.rank()
        if lo is not None and rank < lo:
            return False
        return clone.is_primary or hi is None or rank <= hi

    def _fits_slot(self, engine: "_SlotEngine", req: ServeRequest) -> bool:
        """May ``req`` take a free slot of this engine right now?  Tier
        must sit in the request's placement band and the engine's block
        pool must admit the effective prompt (prefix matching applies)."""
        return (self._in_band(req, engine.clone)
                and engine.kv.can_admit(
                    _SlotEngine.effective_prompt(
                        req, self.prompt_pad, self.backend.capacity),
                    req.max_new_tokens))

    def _demand_buckets(self) -> List[tuple]:
        """Queued demand as ``(required_type, urgent, cohort_units)``
        buckets for the autoscaler, tracked per tenant/priority class and
        per KV-footprint tier (``demand_by_class`` keeps the per-class
        peaks for telemetry)."""
        counts: Dict[tuple, int] = {}
        for r in self.queue.snapshot():
            key = (self._required_type(r), r.priority > 0, r.tenant)
            counts[key] = counts.get(key, 0) + 1
        agg: Dict[tuple, int] = {}
        for (t, urgent, _tenant), n in counts.items():
            agg[(t, urgent)] = agg.get((t, urgent), 0) + n
        for key, n in counts.items():
            self.demand_by_class[key] = max(self.demand_by_class.get(key, 0),
                                            n)
        return [(t, urgent, -(-n // self.max_batch))
                for (t, urgent), n in agg.items()]

    @staticmethod
    def _in_flight_by_type(inflight: Dict) -> Dict[str, int]:
        """In-flight work units per clone type (engines and cohorts)."""
        out: Dict[str, int] = {}
        for unit in inflight.values():
            t = unit.clone.ctype.name
            out[t] = out.get(t, 0) + 1
        return out

    # ---------------------------------------------------------------- cohort
    def _start_cohort(self, batch: List[ServeRequest], clone):
        plen = self.prompt_pad
        toks = np.zeros((len(batch), plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, :min(len(r.prompt), plen)] = r.prompt[:plen]
        cohort = _Cohort(reqs=batch, clone=clone, plen=plen,
                         outs=[[] for _ in batch],
                         first_token_t=[0.0] * len(batch),
                         token_ts=[[] for _ in batch])
        clone.busy = True
        delay = (self.autoscaler.clone_ready_delay(clone, self.clock.now())
                 + self._net_s(toks.nbytes))
        task = self.dispatcher.submit(
            clone, self.backend.prefill, (self.backend.params,
                                          jnp.asarray(toks)),
            executor=self.executor, extra_delay=delay, label="prefill")
        self._charge(clone, task.venue_seconds)
        return task, cohort

    def _submit_decode(self, cohort: _Cohort):
        pos = jnp.int32(min(cohort.plen + cohort.step,
                            self.backend.capacity - 1))
        written = len(cohort.reqs) * min(cohort.plen + cohort.step + 1,
                                         self.backend.capacity)
        self.kv_samples.append((written,
                                len(cohort.reqs) * self.backend.capacity))
        task = self.dispatcher.submit(
            cohort.clone, self.backend.decode,
            (self.backend.params, cohort.cache, cohort.tok, pos),
            executor=self.executor,
            extra_delay=self._net_s(len(cohort.reqs) * 8), label="decode")
        self._charge(cohort.clone, task.venue_seconds)
        return task

    def _retire(self, cohort: _Cohort, completions: List[ServeCompletion]
                ) -> bool:
        """Emit current tokens; drop finished rows.  True while alive."""
        now = self.clock.now()
        tok = np.asarray(cohort.tok)[:, 0]
        keep = []
        for i, r in enumerate(cohort.reqs):
            cohort.outs[i].append(int(tok[i]))
            cohort.token_ts[i].append(now)
            if len(cohort.outs[i]) == 1:
                cohort.first_token_t[i] = now
            if len(cohort.outs[i]) >= r.max_new_tokens:
                self.tokens_emitted += len(cohort.outs[i])
                completions.append(ServeCompletion(
                    r.rid, cohort.outs[i], r.arrival_t,
                    cohort.first_token_t[i], now, cohort.clone.spec.name,
                    tenant=r.tenant, slo=r.slo, deadline_s=r.deadline_s,
                    token_ts=cohort.token_ts[i]))
                t = cohort.clone.ctype.name
                self.fleet_mix[t] = self.fleet_mix.get(t, 0) + 1
            else:
                keep.append(i)
        if not keep:
            self.pool.release([cohort.clone])
            return False
        if len(keep) < len(cohort.reqs):      # leave at step granularity
            cohort.reqs = [cohort.reqs[i] for i in keep]
            cohort.outs = [cohort.outs[i] for i in keep]
            cohort.first_token_t = [cohort.first_token_t[i] for i in keep]
            cohort.token_ts = [cohort.token_ts[i] for i in keep]
            cohort.tok = cohort.tok[np.asarray(keep, np.int32)]
            cohort.cache = self.backend.cache_take(cohort.cache, keep)
        return True

    # ------------------------------------------------------------- slots
    def _start_engine(self, clone) -> _SlotEngine:
        """Engine for ``clone``; the clone's KV pool is allocated once and
        reused (reset) across engine generations — no per-spawn zeros, and
        the prefix index survives, so cached prompts keep paying off.

        A speculative handler (ADR-008) additionally pairs the engine
        with a *draft* clone on the cheapest adequate tier (the
        ``spec_draft`` placement hint) and gives it a fresh draft-model
        pool with the target pool's exact block geometry — the two sides
        share one set of block tables.  No draft clone available means
        the engine simply runs non-speculative (never a stall)."""
        clone.busy = True
        kv = self._kv_pools.get(clone.cid)
        if kv is None:
            kv = KVBlockPool(self.backend, self.max_batch, self.block_size,
                             self._blocks_for_type(clone.ctype.name),
                             prefix_cache=self.prefix_cache)
            self._kv_pools[clone.cid] = kv
        else:
            kv.reset()
        engine = _SlotEngine(self.backend, clone, kv, self.decode_window,
                             self.donate_kv, self.prefill_chunk,
                             self.mixed_dispatch)
        if self.speculative:
            dc = self._acquire_draft_clone(clone)
            if dc is not None:
                engine.spec_on = True
                engine.draft_clone = dc
                engine.spec_k = self.spec_k
                engine.draft_pool = self.backend.init_draft_pool(
                    kv.max_slots, kv.num_blocks, kv.bs)
                if dc.cid not in self.spec_draft_cids:
                    self.spec_draft_cids.append(dc.cid)
            else:
                self.spec_fallbacks += 1
        if self.disagg:
            pc = self._acquire_prefill_clone(clone)
            if pc is not None:
                engine.disagg_on = True
                engine.prefill_clone = pc
                ppool = self._prefill_pools.get(clone.cid)
                if ppool is None:
                    # scratch pool: worst-case blocks (it holds at most
                    # max_batch in-flight prompts), no prefix index — the
                    # partner's content is transient by design
                    ppool = KVBlockPool(self.backend, self.max_batch,
                                        self.block_size, None,
                                        prefix_cache=False)
                    self._prefill_pools[clone.cid] = ppool
                else:
                    ppool.reset()
                engine.prefill_pool = ppool
            else:
                self.disagg_fallbacks += 1
        return engine

    def _acquire_prefill_clone(self, decode_clone):
        """Attach the engine to the SHARED disagg partner clone
        (ADR-009), refcounted: the first engine claims a clone of the
        prefill tier — a free RUNNING clone preferred, else one resumed/
        booted through the pool lifecycle — and later engines just bump
        the refcount.  The decode clone itself is never a candidate.
        None degrades the engine to co-located prefill — never a
        stall."""
        if (self._partner_clone is not None
                and self._partner_clone.serveable):
            self._partner_refs += 1
            return self._partner_clone
        t = self.disagg_prefill_type
        for c in self.pool.running_secondaries():
            if (c is not decode_clone and not c.busy and c.serveable
                    and c.ctype.name == t):
                c.busy = True
                self._partner_clone, self._partner_refs = c, 1
                return c
        try:
            clones, _ = self.pool.acquire(t, n=1, exclude_primary=True)
        except Exception:
            return None
        for c in clones:
            if c is not decode_clone and c.serveable:
                c.busy = True
                self._partner_clone, self._partner_refs = c, 1
                return c
        self.pool.release(clones)
        return None

    def _release_partner(self) -> None:
        """Drop one engine's reference on the shared partner clone; the
        clone returns to the pool (idle-TTL pause/power-off applies) when
        the last disagg engine lets go."""
        self._partner_refs = max(0, self._partner_refs - 1)
        if self._partner_refs == 0 and self._partner_clone is not None:
            self.pool.release([self._partner_clone])
            self._partner_clone = None

    def _acquire_draft_clone(self, verify_clone):
        """Claim a cheap-tier clone as the engine's draft partner.  The
        placement hint picks the cheapest $-rate tier the fleet offers;
        a free RUNNING clone of that tier is preferred, else one is
        resumed/booted through the pool lifecycle.  The verify clone
        itself is never a candidate (the whole point is overlap)."""
        t = self.placement.choose_type(self.fleet[0], hint="spec_draft") \
            or self.fleet[0]
        for c in self.pool.running_secondaries():
            if (c is not verify_clone and not c.busy and c.serveable
                    and c.ctype.name == t):
                c.busy = True
                return c
        try:
            clones, _ = self.pool.acquire(t, n=1, exclude_primary=True)
        except Exception:
            return None
        for c in clones:
            if c is not verify_clone and c.serveable:
                c.busy = True
                return c
        self.pool.release(clones)
        return None

    def _release_engine(self, engine: _SlotEngine) -> None:
        """Return an engine's clone — and its draft partner — to the
        pool."""
        clones = [engine.clone]
        if engine.draft_clone is not None:
            clones.append(engine.draft_clone)
            engine.draft_clone = None
            engine.spec_on = False
        if engine.prefill_clone is not None:
            engine.prefill_clone = None
            engine.disagg_on = False
            self._release_partner()
        self.pool.release(clones)

    def _admit(self, engine: _SlotEngine, req: ServeRequest) -> None:
        """Admit through the engine, folding the admission's prefix-cache
        economics into the handler's report counters.  Disagg-eligible
        cold prompts are intercepted first (ADR-009): they allocate a
        decode-side slot but defer the prefill to the engine's partner
        clone."""
        st = self._clone_stat(engine.clone)
        if self._try_disagg_admit(engine, req):
            plen = self.prompt_pad      # fresh eff is exactly pad-long
            self.prompt_tokens += plen
            st["prompt_tokens"] += plen
            return
        info = engine.admit(req, self.prompt_pad)
        self.prefix_hit_tokens += info["cached"]
        self.prompt_tokens += info["prompt"]
        st["prefix_hit_tokens"] += info["cached"]
        st["prompt_tokens"] += info["prompt"]
        if info["restore"]:
            self.restored_tokens += info["suffix"]

    def _try_disagg_admit(self, engine: _SlotEngine,
                          req: ServeRequest) -> bool:
        """Route a cold prompt to the disaggregated prefill path when the
        transfer-cost planner says the partner's compute win beats the
        KV wire cost (ADR-009).  Local prefix hits always stay
        co-located — reusing resident blocks is strictly cheaper than
        recomputing the prefix remotely and shipping it back."""
        if not (engine.disagg_on and engine.prefill_clone is not None):
            return False
        if req.generated:          # restore path: suffix scan is local
            return False
        eff = _SlotEngine.effective_prompt(req, self.prompt_pad,
                                           engine.kv.capacity)
        if engine.kv.match_prefix(eff)[2] > 0:
            return False
        if not self._disagg_worth(engine, len(eff)):
            self.disagg_colocated += 1
            return False
        slot, new_ids, _, _ = engine.kv.alloc_slot(
            eff, req.max_new_tokens, force_suffix=True)
        ids = [int(b) for b in new_ids]
        engine.disagg_joins.append((slot, req, eff, ids))
        engine.disagg_blocks[slot] = ids
        return True

    def _preempt_slot(self, engine: _SlotEngine, victim: int,
                      counts: np.ndarray) -> None:
        """Evict ``victim`` under pool pressure: carry its generated
        tokens and TTFT stamp on the request, reclaim its blocks (shared
        prompt blocks stay resident in the prefix index, so the restore
        is prefix-accelerated), and requeue it at the queue head."""
        s = engine.slots[victim]
        req = s.req
        req.generated = list(s.out)
        req.first_token_t = s.first_token_t
        req.token_ts = list(s.token_ts)
        req.preemptions += 1
        engine.slots[victim] = None
        engine.tok_host[victim] = 0
        counts[victim] = 0
        engine.kv.free_slot(victim)
        self.queue.requeue(req)
        self.preemptions += 1

    def _cancel_join(self, engine: _SlotEngine) -> None:
        """Roll back the newest not-yet-submitted join under pool
        pressure: its prefill never ran, so nothing is lost — the slot
        and blocks return to the pool and the request requeues at the
        head.  Always preferred over preempting an *active* slot, whose
        restore re-computes real work.  Pending inbound migrations are
        rolled back last-resort-before-preemption: the device copy never
        ran, so the request downgrades to the restore recovery path with
        its generated tokens carried (ADR-006)."""
        if engine.sfx_joins:
            slot, req, _, _, _ = engine.sfx_joins.pop()
        elif engine.joins:
            slot, req, _, _ = engine.joins.pop()
        elif engine.disagg_joins:
            # partner prefill not yet submitted: free rollback too
            slot, req, _, _ = engine.disagg_joins.pop()
            engine.disagg_blocks.pop(slot, None)
            engine.kv.cancel_slot(slot)
            self.queue.requeue(req)
            self.preemptions += 1
            return
        else:
            m = engine.migrations.pop()
            slot, req, out, ft = m[0], m[1], m[2], m[3]
            kind = m[9] if len(m) > 9 else "recover"
            req.generated = list(out)
            req.first_token_t = ft
            req.preemptions += 1
            if kind == "disagg":
                # prompt blocks were suffix-indexed at admit; the partner
                # slot holding the computed KV is dropped with the copy
                engine.disagg_blocks.pop(slot, None)
                engine.kv.cancel_slot(slot)
                if engine.prefill_pool is not None:
                    engine.prefill_pool.free_slot(m[7])
            else:
                engine.kv.free_slot(slot)   # int-admitted: nothing indexed
                self.recoveries_restored += 1
            self.queue.requeue(req)
            self.preemptions += 1
            return
        engine.cow_pairs = [p for p in engine.cow_pairs if p[0] != slot]
        engine.kv.cancel_slot(slot)
        self.queue.requeue(req)
        self.preemptions += 1

    def _grow_or_preempt(self, engine: _SlotEngine,
                         counts: np.ndarray) -> None:
        """Reserve the window's blocks, shedding load on exhaustion — the
        replacement for the old hard ``RuntimeError``: first roll back
        pending joins (free), then preempt active victims (restorable).
        Each retry frees one slot's private blocks; the loop terminates
        because either growth succeeds or the engine runs out of victims
        (a single slot whose context cannot fit the pool is
        unservable)."""
        kv = engine.kv
        while True:
            try:
                kv.grow_for_window(counts)
                return
            except PoolExhausted:
                if (engine.joins or engine.sfx_joins or engine.migrations
                        or engine.disagg_joins):
                    self._cancel_join(engine)
                    continue
                cands = [(slot, s.req.priority, len(s.out))
                         for slot, s in enumerate(engine.slots)
                         if s is not None and kv.active[slot]]
                if len(cands) <= 1:
                    raise RuntimeError(
                        "KV block pool cannot hold a single request's "
                        f"context (num_blocks={kv.num_blocks}, "
                        f"block_size={kv.bs}): preemption has no victim "
                        "left — raise num_blocks or lower capacity")
                # prefer victims whose restore context fits capacity: a
                # slot decoding past capacity keeps overwriting the last
                # cell, an overwrite history a re-prefill cannot replay,
                # so evicting one forfeits restore token-identity — only
                # done when no in-capacity victim remains
                safe = [c for c in cands
                        if self.prompt_pad + c[2] - 1
                        <= self.backend.capacity]
                self._preempt_slot(
                    engine, self.ledger.pick_victim(safe or cands),
                    counts)

    def _submit_engine_step(self, engine: _SlotEngine):
        """One dispatched unit of engine work: fold every pending join's
        prefill into the step — full batched prefill for cold prompts,
        device block copies for CoW splits, a suffix scan for prefix-hit
        and restored rows — then decode a multi-token *window* for all
        previously-active slots (one device dispatch for up to
        ``decode_window`` tokens per slot; rows at their budget park
        mid-window writes in the trash block).  In-closure order matters:
        full prefills write the blocks the same round's CoW copies read,
        and both precede the suffix scans that attend over them.

        The dispatched closure is *pure* over its bound arguments (the
        Venue executor re-runs it to stabilize timing), so all block/slot
        bookkeeping — including preemption — happens here on the host
        before submission.
        """
        kv = engine.kv
        spec = engine.spec_on and engine.draft_clone is not None
        # tokens each slot will emit this window: min(window, budget left).
        # A speculative round sizes each row's window as its *verify*
        # width k_i + 1 instead (ADR-008): k_i adapts to the row's draft
        # acceptance EMA, clamped so (a) at least the current token is
        # scored, (b) the budget can absorb a full accept (k <= left - 1),
        # and (c) no window write ever needs the capacity - 1 pin
        # (k <= capacity - 1 - pos — a pinned write would collapse
        # last-live-wins and break stepwise token identity).
        counts = np.zeros((kv.max_slots,), np.int32)
        for slot in np.nonzero(kv.active)[0]:
            s = engine.slots[slot]
            left = s.req.max_new_tokens - len(s.out)
            if spec:
                p = int(kv.pos[slot])
                room = max(kv.capacity - 1 - min(p, kv.capacity - 1), 0)
                k = max(1, int(round(s.req.spec_ema * engine.spec_k)))
                k = max(min(k, engine.spec_k, left - 1, room), 0)
                counts[slot] = k + 1
            else:
                counts[slot] = min(engine.window, left)
        if counts.any():
            # whole window's blocks up front; exhaustion rolls back
            # pending joins / preempts victims (zeroing their counts)
            # instead of raising — must run before the join lists are
            # taken, so rollback can still un-admit them
            self._grow_or_preempt(engine, counts)
        joins, engine.joins = engine.joins, []
        sfx, engine.sfx_joins = engine.sfx_joins, []
        cow, engine.cow_pairs = engine.cow_pairs, []
        migs, engine.migrations = engine.migrations, []
        engine.submitted_joins = joins
        engine.submitted_sfx = sfx
        engine.submitted_migrations = migs
        rows = np.nonzero(kv.active)[0]
        do_decode = rows.size > 0
        engine.decode_rows = rows if do_decode else None
        if do_decode:
            # written-token sample: writes past capacity pin to the last
            # cell (same clamp the host fold applies to kv.pos), so they
            # must not count as newly written either
            eff = np.minimum(counts, np.maximum(kv.capacity - kv.pos, 0))
            written = kv.written_tokens() + int(eff.sum())
            self.kv_samples.append((written, kv.used_blocks() * kv.bs))
        engine.decode_counts = counts
        tables = engine.device_tables()      # re-uploaded only when dirty
        pos = jnp.asarray(np.minimum(kv.pos, self.backend.capacity - 1))
        tok = jnp.asarray(engine.tok_host[:, None])
        steps_left = jnp.asarray(counts)
        prefill_into = engine.prefill_into
        decode_window = engine.decode_window
        nbytes = 8 * int(counts.sum())
        join_batch = None
        if joins:
            # joins landing at the same boundary prefill as ONE batched
            # call, padded to a power-of-two bucket so the prefill only
            # ever compiles for log2(max_batch) join counts.  Pad rows
            # scatter nowhere: slot id ``max_slots`` is out of range
            # (state-row update dropped) and block id 0 is the trash block.
            j = len(joins)
            jpad = pow2_bucket(j)
            toks = jnp.concatenate(
                [t for _, _, t, _ in joins]
                + [jnp.zeros((jpad - j,) + joins[0][2].shape[1:],
                             jnp.int32)] * (jpad > j), axis=0)
            blks = jnp.concatenate(
                [jnp.stack([b for _, _, _, b in joins])]
                + [jnp.zeros((jpad - j, joins[0][3].shape[0]),
                             jnp.int32)] * (jpad > j), axis=0)
            slots = jnp.asarray([s for s, _, _, _ in joins]
                                + [kv.max_slots] * (jpad - j), jnp.int32)
            join_batch = (toks, blks, slots)
            nbytes += int(toks.nbytes)
        cow_batch = None
        if cow:
            # CoW splits as one fused device copy; (0, 0) pads are no-ops
            cpad = pow2_bucket(len(cow))
            src = jnp.asarray([s for _, s, _ in cow]
                              + [0] * (cpad - len(cow)), jnp.int32)
            dst = jnp.asarray([d for _, _, d in cow]
                              + [0] * (cpad - len(cow)), jnp.int32)
            cow_batch = (self.backend.copy_fn(self.donate_kv), src, dst)
            nbytes += int(src.nbytes) * 2
        mig_batches = []
        xfer_s = 0.0
        if migs:
            # inbound KV migrations: one fused cross-pool copy per
            # (source pool, kind) — block ids padded to a power-of-two
            # bucket with (0, 0) trash-to-trash no-ops, destination
            # state-row pads dropped via an out-of-range slot id.  The
            # *real* KV bytes cross the inter-clone link: recovery moves
            # (ADR-006) bill into nbytes on the generic net model, while
            # disagg handoffs (ADR-009) bill per *block* on the
            # configured LinkProfile — optionally int8-compressed in
            # flight, which both shrinks the modeled bytes ~4x and
            # round-trips the payload through the real quantize /
            # dequantize device ops.
            by_src: Dict[tuple, list] = {}
            for m in migs:
                kind = m[9] if len(m) > 9 else "recover"
                by_src.setdefault((id(m[4]), kind), []).append(m)
            for (_, kind), group in by_src.items():
                src_pool = group[0][4]
                sids = [b for m in group for b in m[5]]
                dids = [b for m in group for b in m[6]]
                n_blk = len(sids)
                bpad = pow2_bucket(n_blk)
                sids += [0] * (bpad - n_blk)
                dids += [0] * (bpad - n_blk)
                spad = pow2_bucket(len(group))
                sslots = [m[7] for m in group] + [0] * (spad - len(group))
                dslots = [m[0] for m in group] \
                    + [kv.max_slots] * (spad - len(group))
                compress = kind == "disagg" and self.disagg_compress
                # positional arg only when compressing: stub backends
                # (tests) expose the legacy zero-arg migrate_fn
                mfn = (self.backend.migrate_fn(True) if compress
                       else self.backend.migrate_fn())
                mig_batches.append(
                    (mfn, src_pool,
                     jnp.asarray(sids, jnp.int32),
                     jnp.asarray(dids, jnp.int32),
                     jnp.asarray(sslots, jnp.int32),
                     jnp.asarray(dslots, jnp.int32)))
                if kind == "disagg":
                    dbytes = n_blk * self._disagg_block_bytes()
                    dt = transfer_time(dbytes, LINKS[self.disagg_link])
                    xfer_s += dt
                    self.kv_transfer_bytes += dbytes
                    self.kv_transfer_s += dt
                    st = self._clone_stat(engine.clone)
                    st["kv_transfer_bytes"] += dbytes
                    st["kv_transfer_s"] += dt
                else:
                    nbytes += int(sum(m[8] for m in group)
                                  * self._kv_token_bytes())
        sfx_batch = None
        mixed_batch = None
        sfx_steps = 0
        mix_steps = 0
        if sfx:
            # prefix-hit / restore rows: suffix-only prefill as ONE
            # teacher-forced scan, rows and steps padded to power-of-two
            # buckets (pad rows carry n_tok=0 -> trash block)
            j2 = len(sfx)
            jpad2 = pow2_bucket(j2)
            t_max = max(len(s_) for _, _, s_, _, _ in sfx)
            tpad = pow2_bucket(t_max)
            stoks = np.zeros((jpad2, tpad), np.int32)
            spos = np.zeros((jpad2,), np.int32)
            sn = np.zeros((jpad2,), np.int32)
            stabs = np.zeros((jpad2, kv.max_blk), np.int32)
            for i, (slot, _, s_, pos0, _) in enumerate(sfx):
                stoks[i, :len(s_)] = s_
                spos[i] = pos0
                sn[i] = len(s_)
                stabs[i] = kv.tables[slot]
            chunk = engine.chunk
            sfx_steps = -(-tpad // chunk) if chunk else tpad
            if engine.mixed and do_decode:
                # ADR-005 fused step: the suffix chunks ride INSIDE the
                # decode window's scan — one sequential pass covers both
                # tiles, so the join/restore adds max(0, chunks - window)
                # scan steps instead of a whole serial prefill dispatch
                mix_steps = max(engine.window, sfx_steps)
                mixed_batch = (self.backend.mixed_fn(
                    kv.bs, chunk, mix_steps, self.donate_kv),
                    jnp.asarray(stoks), jnp.asarray(spos), jnp.asarray(sn),
                    jnp.asarray(stabs))
                sfx_steps = 0
            elif chunk:
                sfx_batch = (self.backend.prefill_window_fn(
                    kv.bs, tpad, self.donate_kv, chunk=chunk),
                    jnp.asarray(stoks), jnp.asarray(spos), jnp.asarray(sn),
                    jnp.asarray(stabs))
            else:
                sfx_batch = (self.backend.prefill_window_fn(
                    kv.bs, tpad, self.donate_kv),
                    jnp.asarray(stoks), jnp.asarray(spos), jnp.asarray(sn),
                    jnp.asarray(stabs))
            nbytes += int(stoks.nbytes)

        if spec and do_decode:
            return self._submit_spec_round(
                engine, counts, rows, join_batch, cow_batch, mig_batches,
                sfx_batch, sfx_steps, tables, pos, nbytes)

        def step_fn(params, pool, tok, pos, steps_left, tables):
            for mfn, spool, sids, dids, sslots, dslots in mig_batches:
                pool = mfn(pool, spool, sids, dids, sslots, dslots)
            firsts = None
            if join_batch is not None:
                toks, blks, slots = join_batch
                firsts, pool = prefill_into(params, toks, pool, blks, slots)
            if cow_batch is not None:
                copy_into, src, dst = cow_batch
                pool = copy_into(pool, src, dst)
            firsts_sfx = None
            nxt = None
            if mixed_batch is not None:
                mw, stoks, spos, sn, stabs = mixed_batch
                nxt, firsts_sfx, pool = mw(params, pool, tok, pos,
                                           steps_left, tables,
                                           stoks, spos, sn, stabs)
            else:
                if sfx_batch is not None:
                    pw, stoks, spos, sn, stabs = sfx_batch
                    firsts_sfx, pool = pw(params, pool, stoks, spos, sn,
                                          stabs)
                if do_decode:
                    nxt, pool = decode_window(params, pool, tok, pos,
                                              steps_left, tables)
            return firsts, firsts_sfx, nxt, pool

        # sequential scan steps this dispatch executes — what a step-aware
        # executor bills (benchmarks/serving_load.py's mixed sweep): the
        # batched join prefill and the CoW copy are one parallel pass each;
        # the suffix scan and decode window are sequential scans, fused
        # into max(..) steps by the mixed path instead of added serially
        step_fn.seq_steps = (
            int(join_batch is not None) + int(cow_batch is not None)
            + len(mig_batches)
            + (mix_steps if mixed_batch is not None
               else sfx_steps + (engine.window if do_decode else 0)))
        # prompt tokens the batched co-located prefill folded this step —
        # lets a step-aware executor bill the full prefill compute (the
        # disagg sweep's fairness hinge: chunked partner prefills bill
        # per chunk, so the one-shot batched path must not ride free)
        step_fn.prefill_tokens = (int(join_batch[0].shape[1])
                                  if join_batch is not None else 0)
        delay = (self.autoscaler.clone_ready_delay(engine.clone,
                                                   self.clock.now())
                 + self._net_s(nbytes) + xfer_s)
        task = self.dispatcher.submit(
            engine.clone, step_fn,
            (self.backend.params, kv.pool, tok, pos, steps_left, tables),
            executor=self.executor, extra_delay=delay,
            label="step" if do_decode else "prefill")
        self._charge(engine.clone, task.venue_seconds)
        engine.main_inflight = True
        return task

    # ----------------------------------------------------- disagg prefill
    def _submit_disagg_prefill(self, engine: _SlotEngine):
        """Dispatch every pending disagg admission as ONE chunked paged
        prefill on the engine's partner clone (ADR-009).  The partner
        writes into its own scratch pool; the handoff back to the decode
        pool rides the engine's next step as a ``"disagg"``-kind
        migration (billed on ``disagg_link``, optionally int8-compressed
        in flight).  Returns the dispatched task or None."""
        if not (engine.disagg_on and engine.prefill_clone is not None
                and not engine.disagg_inflight and engine.disagg_joins):
            return None
        ppool = engine.prefill_pool
        rows, engine.disagg_joins = engine.disagg_joins, []
        sub = []
        for slot, req, eff, new_ids in rows:
            # bare-length alloc: the scratch pool never indexes prompts,
            # so it yields exactly the decode side's block count
            pslot, p_ids, _, _ = ppool.alloc_slot(len(eff))
            sub.append((slot, req, eff, new_ids, pslot,
                        [int(b) for b in p_ids]))
        engine.submitted_disagg = sub
        j = len(sub)
        jpad = pow2_bucket(j)
        tpad = pow2_bucket(max(len(e) for _, _, e, _, _, _ in sub))
        ptoks = np.zeros((jpad, tpad), np.int32)
        ppos = np.zeros((jpad,), np.int32)
        pn = np.zeros((jpad,), np.int32)
        ptabs = np.zeros((jpad, ppool.max_blk), np.int32)
        for k, (_s, _r, eff, _n, pslot, _p) in enumerate(sub):
            ptoks[k, :len(eff)] = eff
            pn[k] = len(eff)
            ptabs[k] = ppool.tables[pslot]
        chunk = engine.chunk
        if chunk:
            pw = self.backend.prefill_window_fn(ppool.bs, tpad, False,
                                                chunk=chunk)
        else:
            pw = self.backend.prefill_window_fn(ppool.bs, tpad, False)

        def disagg_fn(params, pool, toks, pos0, n_tok, tabs):
            return pw(params, pool, toks, pos0, n_tok, tabs)

        disagg_fn.seq_steps = -(-tpad // chunk) if chunk else tpad
        disagg_fn.prefill_tokens = 0     # chunk-billed via seq_steps
        toks_d = jnp.asarray(ptoks)
        delay = (self.autoscaler.clone_ready_delay(engine.prefill_clone,
                                                   self.clock.now())
                 + self._net_s(int(toks_d.nbytes)))
        task = self.dispatcher.submit(
            engine.prefill_clone, disagg_fn,
            (self.backend.params, ppool.pool, toks_d, jnp.asarray(ppos),
             jnp.asarray(pn), jnp.asarray(ptabs)),
            executor=self.executor, extra_delay=delay,
            label="disagg_prefill")
        self._charge(engine.prefill_clone, task.venue_seconds)
        engine.disagg_inflight = True
        return task

    def _disagg_prefill_done(self, engine: _SlotEngine, task) -> None:
        """Fold a completed partner prefill: stamp TTFT now (the first
        token exists the moment the partner finishes — streaming it back
        costs token bytes, not the KV handoff), then queue each row's
        block copy into the engine's next step as a disagg migration."""
        firsts, ppool_dev = task.value
        if engine.prefill_pool is not None:
            engine.prefill_pool.pool = ppool_dev
        engine.disagg_inflight = False
        sub, engine.submitted_disagg = engine.submitted_disagg, []
        now = self.clock.now()
        firsts = np.asarray(firsts)
        for (slot, req, eff, new_ids, pslot, p_ids), ft in zip(sub,
                                                               firsts):
            req.first_token_t = now
            req.token_ts = [now]
            engine.migrations.append(
                (slot, req, [int(ft)], now, ppool_dev, p_ids, new_ids,
                 pslot, len(eff), "disagg"))

    def _pump(self, engine: _SlotEngine, inflight: Dict) -> None:
        """Submit whatever the engine can run *now*: its own next step
        (unless one is already in flight) and, independently, a partner
        prefill for pending disagg admissions.  The two overlap — the
        decode clone keeps stepping while the partner prefills, which is
        the entire point of the disaggregation (ADR-009)."""
        if engine.step_work() and not engine.main_inflight:
            task = self._submit_engine_step(engine)
            engine.main_inflight = True
            inflight[task] = engine
            self._maybe_hedge(task, engine, inflight)
        pt = self._submit_disagg_prefill(engine)
        if pt is not None:
            inflight[pt] = engine

    # ------------------------------------------------------- speculative
    def _slot_history(self, engine: _SlotEngine, slot: int) -> List[int]:
        """Tokens resident at positions ``0 .. kv.pos[slot] - 1`` of a
        slot — the committed context the draft model's catch-up replays
        (the *current* token at ``kv.pos`` is the decode input, read from
        ``tok_host``, never from here)."""
        s = engine.slots[slot]
        base = np.zeros((self.prompt_pad,), np.int32)
        pr = s.req.prompt
        base[:min(len(pr), self.prompt_pad)] = pr[:self.prompt_pad]
        seq = base.tolist() + list(s.out)
        return seq[:int(engine.kv.pos[slot])]

    def _submit_spec_round(self, engine: _SlotEngine, counts, rows,
                           join_batch, cow_batch, mig_batches, sfx_batch,
                           sfx_steps, tables, pos, nbytes):
        """Dispatch one speculative round: stash the verify closure
        (which carries the round's join/CoW/migration/suffix folds), then
        fire the *draft* dispatch on the cheap-tier partner clone
        (ADR-008).  The verify is submitted when the draft completes —
        or immediately with zero drafts when every row's window clamped
        to k = 0 (capacity edge / one-token budgets), where the verify
        degenerates to a plain decode step."""
        kv = engine.kv
        act = kv.active.astype(bool)
        k_arr = np.maximum(counts - 1, 0).astype(np.int32)
        n_live = jnp.asarray(counts)
        tok_snap = engine.tok_host.copy()
        prefill_into = engine.prefill_into
        v_fn = self.backend.spec_verify_fn(kv.bs)
        params, pool0 = self.backend.params, kv.pool

        def verify_builder(drafts_np):
            x = np.concatenate([tok_snap[:, None],
                                drafts_np.astype(np.int32)], axis=1)

            def step_fn(params, pool, toks, pos, n_live, tables):
                for mfn, spool, sids, dids, sslots, dslots in mig_batches:
                    pool = mfn(pool, spool, sids, dids, sslots, dslots)
                firsts = None
                if join_batch is not None:
                    jtoks, blks, slots = join_batch
                    firsts, pool = prefill_into(params, jtoks, pool, blks,
                                                slots)
                if cow_batch is not None:
                    copy_into, src, dst = cow_batch
                    pool = copy_into(pool, src, dst)
                firsts_sfx = None
                if sfx_batch is not None:
                    pw, stoks, spos, sn, stabs = sfx_batch
                    firsts_sfx, pool = pw(params, pool, stoks, spos, sn,
                                          stabs)
                greedy, pool = v_fn(params, pool, toks, pos, n_live, tables)
                return firsts, firsts_sfx, greedy, pool

            # the chunked verify scores every window position in ONE
            # sequential pass — that is the dispatches-per-token win
            step_fn.seq_steps = (int(join_batch is not None)
                                 + int(cow_batch is not None)
                                 + len(mig_batches) + sfx_steps + 1)
            args = (params, pool0, jnp.asarray(x), pos, n_live, tables)
            return step_fn, args, nbytes + int(x.nbytes)

        engine._verify_builder = verify_builder
        engine._spec_round = k_arr
        if int(k_arr.sum()) == 0:
            return self._submit_spec_verify(
                engine, np.zeros((kv.max_slots, engine.spec_k), np.int32),
                np.zeros((kv.max_slots,), np.int32))
        # --- draft dispatch: catch-up (committed tokens the draft pool
        # has not ingested) + k greedy proposal steps, one jitted call ---
        n_c = np.where(act, kv.pos - engine.dpos, 0).astype(np.int32)
        tcpad = pow2_bucket(max(int(n_c.max()), 1))
        ctoks = np.zeros((kv.max_slots, tcpad), np.int32)
        for slot in rows:
            if n_c[slot] > 0:
                hist = self._slot_history(engine, slot)
                ctoks[slot, :n_c[slot]] = hist[int(engine.dpos[slot]):]
        d_fn = self.backend.spec_draft_fn(kv.bs, tcpad, engine.spec_k)

        def draft_step(dparams, dpool, ctoks, cpos0, n_c, tok, pos,
                       k_live, tables):
            return d_fn(dparams, dpool, ctoks, cpos0, n_c, tok, pos,
                        k_live, tables)

        draft_step.seq_steps = tcpad + engine.spec_k
        draft_step.step_scale = self.draft_cost
        dargs = (self.backend.draft_params, engine.draft_pool,
                 jnp.asarray(ctoks),
                 jnp.asarray(np.where(act, engine.dpos, 0)),
                 jnp.asarray(n_c), jnp.asarray(tok_snap[:, None]),
                 pos, jnp.asarray(k_arr), tables)
        delay = (self.autoscaler.clone_ready_delay(engine.draft_clone,
                                                   self.clock.now())
                 + self._net_s(int(ctoks.nbytes)))
        task = self.dispatcher.submit(
            engine.draft_clone, draft_step, dargs, executor=self.executor,
            extra_delay=delay, label="draft")
        self._charge(engine.draft_clone, task.venue_seconds)
        return task

    def _spec_draft_done(self, engine: _SlotEngine, task):
        """Fold a completed draft dispatch: take the draft pool update
        and the proposals (bench harnesses corrupt them here,
        deterministically), advance the draft cursors past what the
        draft ingested+proposed, and chain the verify dispatch."""
        drafts, dpool = task.value
        engine.draft_pool = dpool
        drafts = np.asarray(drafts, np.int32)
        k_arr = engine._spec_round
        if self.spec_corruption > 0:
            vocab = getattr(getattr(self.backend, "cfg", None),
                            "vocab_size", None)
            flips = self._spec_rng.random(drafts.shape) \
                < self.spec_corruption
            bumped = drafts + 1 if vocab is None else (drafts + 1) % vocab
            drafts = np.where(flips, bumped, drafts).astype(np.int32)
        rows = engine.decode_rows
        if rows is not None:
            engine.dpos[rows] = engine.kv.pos[rows] + k_arr[rows]
        return self._submit_spec_verify(engine, drafts, k_arr)

    def _submit_spec_verify(self, engine: _SlotEngine, drafts: np.ndarray,
                            n_spec: np.ndarray):
        """Dispatch the stashed verify closure with the round's draft
        proposals — or all-zero drafts with ``n_spec = 0`` when the
        draft clone died mid-round: the verify then accepts nothing and
        emits exactly one plain greedy token per row, preserving the
        round's join/CoW/migration folds (the cohort never stalls)."""
        builder, engine._verify_builder = engine._verify_builder, None
        step_fn, args, nbytes = builder(drafts)
        engine.spec_pending = (drafts, np.asarray(n_spec, np.int32))
        delay = (self.autoscaler.clone_ready_delay(engine.clone,
                                                   self.clock.now())
                 + self._net_s(nbytes))
        task = self.dispatcher.submit(
            engine.clone, step_fn, args, executor=self.executor,
            extra_delay=delay, label="step")
        self._charge(engine.clone, task.venue_seconds)
        return task

    def _engine_step_done(self, engine: _SlotEngine, task,
                          completions: List[ServeCompletion]) -> bool:
        """Fold a completed step back into host state.  True while alive."""
        now = self.clock.now()
        firsts, firsts_sfx, nxt, pool = task.value
        kv = engine.kv
        kv.pool = pool
        firsts = [] if firsts is None else np.asarray(firsts)
        for (slot, req, _, _), ft in zip(engine.submitted_joins, firsts):
            t0 = int(ft)
            engine.slots[slot] = _Slot(req, [t0], now, token_ts=[now])
            engine.tok_host[slot] = t0
            engine.dpos[slot] = 0       # draft replays full history
            kv.active[slot] = True
        engine.submitted_joins = []
        firsts_sfx = [] if firsts_sfx is None else np.asarray(firsts_sfx)
        for (slot, req, _, _, restore), ft in zip(engine.submitted_sfx,
                                                  firsts_sfx):
            if restore:
                # resume where preemption stopped: generated tokens were
                # already emitted (TTFT stamp preserved), the last one is
                # the next decode input — the scan's final logits only
                # re-derive it, so the stored token is authoritative
                t0 = int(req.generated[-1])
                engine.slots[slot] = _Slot(
                    req, list(req.generated), req.first_token_t,
                    token_ts=_carried_ts(req, len(req.generated)))
            else:
                t0 = int(ft)
                engine.slots[slot] = _Slot(req, [t0], now, token_ts=[now])
            engine.tok_host[slot] = t0
            engine.dpos[slot] = 0       # draft replays full history
            kv.active[slot] = True
        engine.submitted_sfx = []
        for m in engine.submitted_migrations:
            slot, req, out, ft = m[0], m[1], m[2], m[3]
            kind = m[9] if len(m) > 9 else "recover"
            # the migrated slot resumes exactly where the source clone
            # stopped: tokens already emitted, the last one is the next
            # decode input (same contract as the restore fold above)
            engine.slots[slot] = _Slot(req, list(out), ft,
                                       token_ts=_carried_ts(req, len(out)))
            engine.tok_host[slot] = int(out[-1])
            engine.dpos[slot] = 0       # draft replays full history
            kv.active[slot] = True
            if kind == "disagg":
                # handoff landed: the partner's scratch slot retires and
                # the slot's prompt blocks leave the pending set below —
                # they are real and shareable from this fold on
                self.disagg_handoffs += 1
                engine.disagg_blocks.pop(slot, None)
                if engine.prefill_pool is not None:
                    engine.prefill_pool.free_slot(m[7])
            else:
                self.recoveries_migrated += 1
        engine.submitted_migrations = []
        kv.clear_pending()
        # disagg slots still awaiting their handoff copy keep their
        # prompt blocks un-shareable: clear_pending() is index-global, so
        # re-pin them until the fold above retires each slot (ADR-009)
        for ids in engine.disagg_blocks.values():
            kv._pending.update(int(b) for b in ids)
        if engine.decode_rows is not None and nxt is not None:
            nxt = np.asarray(nxt)                       # (S, window)
            rows = engine.decode_rows
            spec_pend, engine.spec_pending = engine.spec_pending, None
            if spec_pend is not None:
                # speculative fold (ADR-008): the verify grid scored the
                # current token plus every draft; accept the longest
                # agreeing prefix and emit one extra target token — the
                # emitted stream is bitwise the stepwise greedy stream
                drafts, n_spec = spec_pend
                acc = model.spec_accept(nxt, drafts, n_spec)[rows]
                n = (acc + 1).astype(np.int32)
                self.spec_rounds += 1
                self.spec_proposed += int(n_spec[rows].sum())
                self.spec_accepted += int(acc.sum())
                self.spec_tokens += int(n.sum())
                for slot, a in zip(rows, acc.tolist()):
                    k_i = int(n_spec[slot])
                    if k_i > 0:     # EMA drives next round's window K
                        req = engine.slots[slot].req
                        req.spec_ema = (0.5 * req.spec_ema
                                        + 0.5 * (a / k_i))
            else:
                n = engine.decode_counts[rows]          # >= 1 per active row
            # vectorized fold: last live token and the capacity clamp via
            # fancy indexing (the clamp: past capacity the write position
            # pins to the last slot, like the contiguous path, so the
            # written-token count must not keep growing either)
            engine.tok_host[rows] = nxt[rows, n - 1]
            kv.pos[rows] = np.minimum(kv.pos[rows] + n, kv.capacity)
            # draft cursor never runs ahead of the committed context:
            # rejected proposals' KV is garbage on both pools, the next
            # catch-up overwrites it
            engine.dpos[rows] = np.minimum(engine.dpos[rows], kv.pos[rows])
            if spec_pend is not None:
                engine.spec_rounds_done += 1
                self._maybe_drop_speculation(engine)
            # streamed delivery stamps: tokens leave the clone spread
            # across the dispatch interval, so interpolate within
            # [submitted_at, done_at] per row (ADR-007 TTFT/TPOT)
            t0 = getattr(task, "submitted_at", now)
            span = max(now - t0, 0.0)
            for slot, row, k in zip(rows, nxt[rows].tolist(), n.tolist()):
                engine.slots[slot].out.extend(row[:k])
                engine.slots[slot].token_ts.extend(
                    t0 + span * (j + 1) / k for j in range(k))
            engine.decode_rows = None
        for slot, s in enumerate(engine.slots):   # evict at step granularity
            if s is not None and len(s.out) >= s.req.max_new_tokens:
                self.tokens_emitted += len(s.out)
                completions.append(ServeCompletion(
                    s.req.rid, s.out, s.req.arrival_t, s.first_token_t,
                    now, engine.clone.spec.name,
                    tenant=s.req.tenant, slo=s.req.slo,
                    deadline_s=s.req.deadline_s,
                    token_ts=(s.token_ts if len(s.token_ts) == len(s.out)
                              else [])))
                t = engine.clone.ctype.name
                self.fleet_mix[t] = self.fleet_mix.get(t, 0) + 1
                engine.slots[slot] = None
                kv.free_slot(slot)
        return engine.alive()

    def _maybe_drop_speculation(self, engine: _SlotEngine) -> None:
        """Adaptive bail-out (ADR-008): when the cohort's mean
        acceptance EMA collapses, speculation costs more dispatches than
        it saves — release the draft clone and fall back to the plain
        decode window for this engine (sticky; counted as a fallback)."""
        if not engine.spec_on or engine.spec_rounds_done < 3:
            return
        emas = [s.req.spec_ema for s in engine.slots if s is not None]
        if emas and float(np.mean(emas)) < 0.25:
            engine.spec_on = False
            self.spec_fallbacks += 1
            if engine.draft_clone is not None:
                self.pool.release([engine.draft_clone])
                engine.draft_clone = None

    # ------------------------------------------------------- fault recovery
    def _requeue_lost(self, req: ServeRequest) -> None:
        """Send a dead engine's request back through admission on the
        prefix-accelerated restore path (its ``generated`` tokens, if
        any, were already carried onto the request)."""
        req.preemptions += 1
        self.queue.requeue(req)
        self.recoveries_restored += 1

    def _try_migrate(self, src_engine: _SlotEngine, slot: int, s: _Slot,
                     engines: Dict[int, "_SlotEngine"]) -> bool:
        """Queue one active slot of a draining engine for KV migration
        into a survivor with room: claim a destination slot + blocks now
        (so later candidates in the same recovery pass see the
        commitment), defer the device copy into the destination's next
        step closure.  False when no survivor can admit the context."""
        if getattr(self.backend, "migrate_fn", None) is None:
            return False
        kv = src_engine.kv
        pos = int(kv.pos[slot])
        nb = (pos - 1) // kv.bs + 1
        src_ids = [int(b) for b in kv.tables[slot, :nb]]
        cands = sorted(
            (e for e in engines.values()
             if e is not src_engine and e.clone.serveable),
            key=lambda e: e.clone.cid)
        for dst in cands:
            if not dst.kv.can_admit(pos, s.req.max_new_tokens):
                continue
            dslot, new_ids, _, _ = dst.kv.alloc_slot(pos)
            s.req.token_ts = list(s.token_ts)   # stamps survive the move
            dst.migrations.append(
                (dslot, s.req, list(s.out), s.first_token_t,
                 kv.pool, src_ids, [int(b) for b in new_ids], slot, pos,
                 "recover"))
            return True
        return False

    def _recover_engine(self, engine: _SlotEngine, fault: CloneFault,
                        engines: Dict[int, "_SlotEngine"]) -> None:
        """Recover every request a dead engine held (ADR-006).

        Pending/submitted joins and inbound migrations never folded a
        token on this engine, so they simply requeue (suffix/migration
        rows carry their generated tokens).  *Active* slots hold real
        decode progress: a ``drain`` leaves the KV salvageable — migrate
        to a survivor when one can admit the context — while a ``kill``
        lost the device memory, so the request requeues on the restore
        path and re-prefills (prefix-accelerated on a surviving pool).
        """
        for (_, req, _t, _b) in engine.joins + engine.submitted_joins:
            self._requeue_lost(req)
        for (_, req, _s, _p, _r) in engine.sfx_joins + engine.submitted_sfx:
            self._requeue_lost(req)
        for (_, req, out, ft, *_rest) in (engine.migrations
                                          + engine.submitted_migrations):
            req.generated = list(out)
            req.first_token_t = ft
            self._requeue_lost(req)
        # disagg rows parked on the partner never folded a token on THIS
        # engine either: requeue them cold (the partner's scratch pool
        # is transient — nothing to salvage from the decode side)
        for (_, req, _e, _i) in engine.disagg_joins:
            self._requeue_lost(req)
        for (_, req, _e, _i, _ps, _pi) in engine.submitted_disagg:
            self._requeue_lost(req)
        engine.joins, engine.sfx_joins, engine.cow_pairs = [], [], []
        engine.submitted_joins, engine.submitted_sfx = [], []
        engine.migrations, engine.submitted_migrations = [], []
        engine.disagg_joins, engine.submitted_disagg = [], []
        engine.disagg_blocks = {}
        for slot, s in enumerate(engine.slots):
            if s is None:
                continue
            if not (fault.kind == "drain"
                    and self._try_migrate(engine, slot, s, engines)):
                s.req.generated = list(s.out)
                s.req.first_token_t = s.first_token_t
                s.req.token_ts = list(s.token_ts)
                self._requeue_lost(s.req)
            engine.slots[slot] = None
        # the pool object dies with the clone — a revived clone starts
        # from a fresh pool (its prefix index died with the memory); the
        # device arrays stay referenced by any pending migration tuples
        self._kv_pools.pop(engine.clone.cid, None)
        self._prefill_pools.pop(engine.clone.cid, None)

    def _recover_failed(self, inflight: Dict,
                        engines: Dict[int, "_SlotEngine"]) -> None:
        """Handle every clone the injector killed/drained since the last
        round: cancel its in-flight dispatches (their values will never
        arrive), resolve hedge races, and recover its engine's requests."""
        for clone, fault in self.injector.drain_failed():
            draft_orphans = []        # engines whose draft died mid-round
            disagg_orphans = []       # engines whose partner died mid-prefill
            for task in [t for t in inflight if t.clone is clone]:
                unit = inflight.pop(task)
                self.dispatcher.cancel(task)
                if task.label == "draft":
                    # the VERIFY closure (and this round's join/CoW/
                    # migration folds) is stashed on the engine — it can
                    # still run, with zero drafts, on the healthy clone
                    draft_orphans.append(unit)
                    continue
                if task.label == "disagg_prefill":
                    # the decode engine is healthy — its parked rows
                    # requeue / degrade to co-located prefill below
                    disagg_orphans.append(unit)
                    continue
                partner = self._hedges.pop(task, None)
                if partner is not None:
                    self._hedges.pop(partner, None)
                    if task.label == "hedge":
                        continue      # the original keeps racing
                    # the engine's own step died with the clone — its
                    # hedge can't rescue an engine being recovered
                    inflight.pop(partner, None)
                    self.dispatcher.cancel(partner)
                    self.pool.release([partner.clone])
            engine = None
            for key, eng in list(engines.items()):
                if eng.clone is clone:
                    engine = engines.pop(key)
                    self.ledger.drop(key)
                    break
            if engine is not None:
                # a dead verify clone orphans its in-flight draft
                # dispatch (the verify target is gone) and frees the
                # draft partner back to the pool
                for t in [t for t, u in inflight.items() if u is engine]:
                    inflight.pop(t)
                    self.dispatcher.cancel(t)
                if engine.draft_clone is not None:
                    self.pool.release([engine.draft_clone])
                    engine.draft_clone = None
                if engine.prefill_clone is not None:
                    engine.prefill_clone = None
                    engine.disagg_on = False
                    self._release_partner()
                self._recover_engine(engine, fault, engines)
            self.pool.release([clone])
            # draft-clone death degrades its engines to plain decode —
            # they never stall (ADR-008): an interrupted round completes
            # as a zero-draft verify (accepts nothing, emits one plain
            # greedy token per row, folds the round's joins/migrations)
            for eng in engines.values():
                if eng.draft_clone is clone:
                    eng.draft_clone = None
                    eng.spec_on = False
                    self.spec_fallbacks += 1
            for eng in draft_orphans:
                if id(eng) in engines and eng._verify_builder is not None:
                    vt = self._submit_spec_verify(
                        eng,
                        np.zeros((eng.kv.max_slots, max(eng.spec_k, 1)),
                                 np.int32),
                        np.zeros((eng.kv.max_slots,), np.int32))
                    inflight[vt] = eng
            # partner-clone death degrades its engines to co-located
            # prefill (ADR-009): rows mid-flight on the dead partner
            # requeue (no token was ever emitted for them); rows still
            # pending convert to plain joins on the decode clone — the
            # engine never stalls
            for eng in disagg_orphans:
                if id(eng) not in engines:
                    continue            # decode engine died too: handled
                eng.disagg_inflight = False
                sub, eng.submitted_disagg = eng.submitted_disagg, []
                for (slot, req, _e, _i, _ps, _pi) in sub:
                    eng.disagg_blocks.pop(slot, None)
                    eng.kv.cancel_slot(slot)
                    self._requeue_lost(req)
            for eng in engines.values():
                if eng.prefill_clone is not clone:
                    continue
                eng.prefill_clone = None
                eng.prefill_pool = None
                eng.disagg_on = False
                self.disagg_fallbacks += 1
                # the scratch pool's device arrays died with the partner
                self._prefill_pools.pop(eng.clone.cid, None)
                rows, eng.disagg_joins = eng.disagg_joins, []
                for (slot, req, eff, ids) in rows:
                    eng.disagg_blocks.pop(slot, None)
                    eng.joins.append(
                        (slot, req, jnp.asarray(eff[None]),
                         jnp.asarray(np.asarray(ids, np.int32))))
                self._pump(eng, inflight)
            if self._partner_clone is clone:
                # injector-killed partner: every engine's reference died
                # with it (pool.release of the dead clone ran above)
                self._partner_clone = None
                self._partner_refs = 0

    # ---------------------------------------------------------------- hedge
    def _maybe_hedge(self, task, engine: _SlotEngine,
                     inflight: Dict) -> None:
        """Race a straggling decode step on a second clone (ADR-006).

        A step whose timeline duration exceeds the recent-history
        quantile by ``hedge_factor`` gets its (pure) closure re-issued
        on a free serveable clone; the duplicate pays the engine's live
        KV context over the link up front.  Whichever copy completes
        first is folded; the loser's completion event is cancelled."""
        if self.hedge_factor <= 0 or task.label != "step":
            return
        hist = self._step_hist
        fire = (len(hist) >= self.hedge_min_samples
                and task.duration > self.hedge_factor
                * float(np.quantile(hist, self.hedge_quantile)))
        hist.append(task.duration)    # after the decision: never vs itself
        if not fire:
            return
        clone = self._free_clone()
        if clone is None or clone is engine.clone:
            return
        kv = engine.kv
        ctx_tokens = int(kv.pos[kv.active].sum())
        delay = (self.autoscaler.clone_ready_delay(clone, self.clock.now())
                 + self._net_s(int(ctx_tokens * self._kv_token_bytes())))
        clone.busy = True
        dup = self.dispatcher.submit(clone, task.fn, task.fn_args,
                                     executor=self.executor,
                                     extra_delay=delay, label="hedge")
        self._charge(clone, dup.venue_seconds)
        self.hedges_fired += 1
        self._hedges[task] = dup
        self._hedges[dup] = task
        inflight[dup] = engine

    def _resolve_hedge(self, winner, inflight: Dict) -> None:
        """First of a hedge pair completed: cancel the loser, return the
        borrowed clone, score the win if the duplicate got there first."""
        partner = self._hedges.pop(winner, None)
        if partner is None:
            return
        self._hedges.pop(partner, None)
        inflight.pop(partner, None)
        self.dispatcher.cancel(partner)
        hedge = winner if winner.label == "hedge" else partner
        self.pool.release([hedge.clone])
        if winner is hedge:
            self.hedge_wins += 1

    # ------------------------------------------------------------------ run
    def run(self, requests: List[ServeRequest], *,
            drain_idle_s: float = 0.0) -> ServeReport:
        """Serve ``requests`` on the virtual timeline; returns a report.

        The loop (both KV modes): admit due arrivals into the bounded
        queue; in paged mode, *offer queued requests to partially-full
        in-flight engines first* (the :class:`~repro.core.scheduler.
        SlotLedger` admission policy — mid-flight joins); autoscale on the
        residual demand; start new engines/cohorts on free clones; then
        advance time to the next task completion or arrival.
        """
        paged = self.kv_mode == "paged"
        reqs = sorted(requests, key=lambda r: r.arrival_t)
        t_start = self.clock.now()
        i = 0
        inflight: Dict[object, object] = {}        # task -> engine | cohort
        engines: Dict[int, _SlotEngine] = {}       # id -> live engine
        completions: List[ServeCompletion] = []
        notified = 0                    # completions fed back to the gateway
        if self.injector is not None:
            self.injector.arm()             # faults become clock events

        while True:
            now = self.clock.now()
            self._band_cache.clear()        # fresh round, fresh inventory
            while i < len(reqs) and reqs[i].arrival_t <= now + 1e-12:
                # arrivals flow through the gateway when one is present
                # (ADR-007); it decides cache-hit / reject / shed / queue
                # and releases into self.queue under quota + fair share
                if self.gateway is not None:
                    self.gateway.offer(reqs[i], now)
                else:
                    self.queue.offer(reqs[i], now)
                i += 1
            if self.injector is not None:
                # recover clones that died since the last round BEFORE
                # joins/spawns consult the engine set (ADR-006)
                self._recover_failed(inflight, engines)
            if self.gateway is not None:
                gw = self.gateway
                # fleet census AFTER recovery: serveable = healthy clones
                # with closed breakers — breaker opens and DEAD clones
                # shrink the gateway's admission envelope (ADR-006 signal)
                healthy = sum(1 for c in self.pool.clones if c.serveable)
                gw.observe_fleet(healthy, len(self.pool.clones),
                                 self.max_batch * max(healthy, 1))
                while notified < len(completions):
                    gw.observe_completion(completions[notified])
                    notified += 1
                gw.release(now, self.queue,
                           self.queue.max_depth - self.queue.depth)
                completions.extend(gw.drain_cached())
            self._peak_queue_depth = max(
                self._peak_queue_depth,
                self.queue.depth + (self.gateway.queued
                                    if self.gateway is not None else 0))
            if paged and engines:
                # mid-flight joins: fill open slots of in-flight engines
                # before counting residual demand or spawning new ones
                # (block-commitment checked per request via ``fits``)
                for key, eng in engines.items():
                    self.ledger.update(key, eng.kv.free_slots)
                # admit via on_assign so each fits() check sees the block
                # allocations of earlier assignments in the same round;
                # fits() matches the effective prompt against the prefix
                # index, so a shared-prefix request needs only its
                # private blocks free — and vetoes engines outside the
                # request's placement band (ADR-004)
                # affinity routing scores candidate engines by cached-
                # prefix depth (ADR-009); random is the ablation arm
                prefer = None
                if self.routing == "affinity":
                    prefer = (lambda key, r:
                              float(self._affinity_depth(engines[key].kv,
                                                         r)))
                elif self.routing == "random":
                    prefer = lambda key, r: float(self._route_rng.random())
                self.ledger.assign(
                    self.queue,
                    fits=lambda key, r: self._fits_slot(engines[key], r),
                    on_assign=lambda key, r: self._admit(engines[key], r),
                    prefer=prefer)
                # parked engines (only partner work was in flight) may
                # have gained runnable work from the assignments
                for eng in engines.values():
                    self._pump(eng, inflight)
            # demand bucketed per tenant/priority class and KV tier; the
            # placement engine turns buckets into per-type targets
            self.autoscaler.step(now, self._demand_buckets(),
                                 self._in_flight_by_type(inflight))
            # spawn engines/cohorts while an adequate clone is free
            while self.queue.depth > 0:
                # first queued request some free clone can serve: a head
                # whose tier is still provisioning (a booting ``large``)
                # must not head-of-line-block the bulk behind it
                picked = clone = None
                for r in self.queue.snapshot():
                    lo, hi = self._placement_band(r)
                    pc = (self._best_affinity_cid(r)
                          if self.routing == "affinity" else None)
                    clone = self._free_clone(lo, hi, prefer_cid=pc)
                    if clone is not None:
                        picked = r
                        break
                if clone is None:
                    break
                if paged:
                    engine = self._start_engine(clone)

                    # the picked request bypasses the band re-check: the
                    # clone was chosen *for it*, and starting the engine
                    # marks the clone busy, which already shifts the
                    # inventory-dependent placement band
                    def fill(r, picked=picked, engine=engine):
                        if r is picked:
                            return engine.kv.can_admit(
                                _SlotEngine.effective_prompt(
                                    r, self.prompt_pad,
                                    self.backend.capacity),
                                r.max_new_tokens)
                        return self._fits_slot(engine, r)

                    n = 0
                    while n < self.max_batch and self.queue.depth > 0:
                        req = self.queue.take_where(fill)
                        if req is None:
                            break
                        self._admit(engine, req)
                        n += 1
                    if n == 0:
                        raise RuntimeError(
                            "KV block pool too small to hold one request's "
                            "prompt even when empty — preemption has no "
                            f"victim (num_blocks={engine.kv.num_blocks}, "
                            f"prompt_pad={self.prompt_pad}, "
                            f"block_size={self.block_size})")
                    engines[id(engine)] = engine
                    self.ledger.update(id(engine), engine.kv.free_slots)
                    self._pump(engine, inflight)
                else:
                    # the cohort seeds with the *picked* request (the
                    # clone was banded for it — never the possibly
                    # band-blocked FIFO head) and fills with band-
                    # compatible requests in FIFO order
                    batch = []
                    while len(batch) < self.max_batch:
                        req = self.queue.take_where(
                            lambda r: r is picked or self._in_band(r,
                                                                   clone))
                        if req is None:
                            break
                        batch.append(req)
                    task, cohort = self._start_cohort(batch, clone)
                    inflight[task] = cohort
                # spawning changed the pool inventory (clone now busy):
                # placement bands must be re-derived next evaluation
                self._band_cache.clear()

            if inflight:
                # bound the wait so due arrivals are admitted on time and
                # a mid-window clone death is detected when it fires, not
                # when the doomed dispatch would have completed
                next_arrival = reqs[i].arrival_t if i < len(reqs) else None
                next_fault = (self.injector.next_event_time()
                              if self.injector is not None else None)
                next_gw = (self.gateway.next_event_time()
                           if self.gateway is not None else None)
                bound = min((t for t in (next_arrival, next_fault, next_gw)
                             if t is not None and t > now), default=None)
                first_done = min(t.done_at for t in inflight)
                if bound is not None and bound < first_done:
                    self.clock.advance_to(bound)
                    continue
                for task in self.dispatcher.wait_any(list(inflight)):
                    unit = inflight.pop(task, None)
                    if unit is None:
                        continue          # hedge loser already resolved
                    self._resolve_hedge(task, inflight)
                    if paged:
                        if task.label == "draft":
                            # half-round: chain the verify on the target
                            vt = self._spec_draft_done(unit, task)
                            inflight[vt] = unit
                            self._maybe_hedge(vt, unit, inflight)
                            continue
                        if task.label == "disagg_prefill":
                            # partner done: the handoff copy rides the
                            # engine's next step (pumped now — the engine
                            # may have been parked waiting on this)
                            self._disagg_prefill_done(unit, task)
                            self._pump(unit, inflight)
                            continue
                        unit.main_inflight = False
                        if self._engine_step_done(unit, task, completions):
                            self._pump(unit, inflight)
                        else:
                            engines.pop(id(unit), None)
                            self.ledger.drop(id(unit))
                            self._release_engine(unit)
                    else:
                        cohort = unit
                        tok, cohort.cache = task.value
                        cohort.tok = tok[:, None]
                        if cohort.phase == "prefill":
                            cohort.phase = "decode"
                        else:
                            cohort.step += 1
                        if self._retire(cohort, completions):
                            inflight[self._submit_decode(cohort)] = cohort
            elif i < len(reqs):
                self.clock.advance_to(reqs[i].arrival_t)
            elif self.queue.depth > 0:
                # every clone may be dead/tripped with revival + probe
                # events pending — advance to the next clock event and
                # let the breaker half-open probes re-admit capacity
                nxt = (self.clock.next_event_time()
                       if self.injector is not None else None)
                if nxt is not None and nxt > now + 1e-12:
                    self.clock.advance_to(nxt)
                    continue
                raise RuntimeError("requests queued but no clone can run "
                                   "(max_secondaries too small?)")
            elif self.gateway is not None and self.gateway.pending > 0:
                # the gateway still owes work: a quota-blocked head (its
                # bucket's eta) or a scheduled Retry-After replay —
                # advance to the event that unblocks it
                nxt = self.gateway.next_event_time()
                if nxt is None:
                    nxt = self.clock.next_event_time()
                if nxt is not None and nxt > now + 1e-12:
                    self.clock.advance_to(nxt)
                    continue
                raise RuntimeError("gateway holds queued work but no clock "
                                   "event can release it")
            else:
                break

        if drain_idle_s > 0.0:       # let idle TTLs pause the secondaries
            # step the drain in PAUSE_IDLE_TTL chunks so the *second* TTL
            # stage fires too: a clone pauses once idle > PAUSE_IDLE_TTL
            # and powers off only on a later reap with idle > OFF_IDLE_TTL
            # — one big advance would pause but never power off
            end = self.clock.now() + drain_idle_s
            while self.clock.now() < end - 1e-9:
                self.clock.advance(min(PAUSE_IDLE_TTL,
                                       end - self.clock.now()))
                self.pool.reap_idle()
            self.autoscaler.step(self.clock.now(), [], {})

        lat = np.array([c.latency_s for c in completions]) \
            if completions else np.zeros(1)
        ttft = np.array([c.ttft_s for c in completions]) \
            if completions else np.zeros(1)
        makespan = self.clock.now() - t_start - drain_idle_s
        utils = [w / r for w, r in self.kv_samples if r > 0]
        cs_by_type = self.pool.clone_seconds_by_type(self.clock.now())
        # SLO accounting over *offered* requests (ADR-007): work the
        # gateway rejected, shed, or dropped counts as missed
        offered_by_slo: Dict[str, int] = {}
        for r in reqs:
            offered_by_slo[r.slo] = offered_by_slo.get(r.slo, 0) + 1
        met_by_slo: Dict[str, int] = {}
        for c in completions:
            if c.met_deadline:
                met_by_slo[c.slo] = met_by_slo.get(c.slo, 0) + 1
        slo_attainment = {s: met_by_slo.get(s, 0) / n
                          for s, n in offered_by_slo.items() if n}
        good_tokens = sum(len(c.tokens) for c in completions
                          if c.met_deadline)
        by_tenant: Dict[str, List[ServeCompletion]] = {}
        for c in completions:
            by_tenant.setdefault(c.tenant or "", []).append(c)
        per_tenant = {
            t: {"served": float(len(cs)),
                "p50_ttft_s": float(np.percentile(
                    [c.ttft_s for c in cs], 50)),
                "p50_tpot_s": float(np.percentile(
                    [c.tpot_s for c in cs], 50))}
            for t, cs in sorted(by_tenant.items())}
        gw = self.gateway
        per_clone = {
            str(cid): {
                "type": st["type"],
                "prefix_hit_rate": (st["prefix_hit_tokens"]
                                    / max(st["prompt_tokens"], 1)),
                "kv_transfer_bytes": float(st["kv_transfer_bytes"]),
                "kv_transfer_s": float(st["kv_transfer_s"])}
            for cid, st in sorted(self.per_clone_stats.items())}
        return ServeReport(
            completions=completions,
            accepted=self.queue.accepted,
            rejected=self.queue.rejected,
            makespan_s=makespan,
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            p50_ttft_s=float(np.percentile(ttft, 50)),
            tokens_per_s=self.tokens_emitted / max(makespan, 1e-9),
            peak_secondaries=self.autoscaler.peak_secondaries,
            scale_ups=self.autoscaler.scale_ups,
            busy_energy_j=self.busy_energy_j,
            pool_stats=dict(self.pool.stats),
            clone_samples=list(self.autoscaler.samples),
            kv_mode=self.kv_mode,
            kv_util=float(np.mean(utils)) if utils else 0.0,
            kv_reserved_peak=max((r for _, r in self.kv_samples),
                                 default=0),
            prefix_hit_rate=(self.prefix_hit_tokens
                             / max(self.prompt_tokens, 1)),
            preemptions=self.preemptions,
            restored_tokens=self.restored_tokens,
            fleet_mix=dict(self.fleet_mix),
            escalations=len(self._escalated),
            clone_seconds_by_type=cs_by_type,
            cost_usd=self.pool.cost_usd(self.clock.now()),
            energy_j_by_type=dict(self.energy_j_by_type),
            power_offs=self.pool.stats["offs"],
            faults_injected=(self.injector.stats["injected"]
                             if self.injector is not None else 0),
            recoveries_migrated=self.recoveries_migrated,
            recoveries_restored=self.recoveries_restored,
            hedges_fired=self.hedges_fired,
            hedge_wins=self.hedge_wins,
            breaker_opens=sum(c.breaker.opens for c in self.pool.clones),
            slo_attainment=slo_attainment,
            goodput_tps=good_tokens / max(makespan, 1e-9),
            gateway_shed=gw.shed if gw is not None else 0,
            gateway_rejected=gw.rejected if gw is not None else 0,
            gateway_retries=gw.retries if gw is not None else 0,
            cache_hits=gw.cache_hits if gw is not None else 0,
            shed_by_slo=dict(gw.shed_by_slo) if gw is not None else {},
            per_tenant=per_tenant,
            peak_queue_depth=self._peak_queue_depth,
            spec_rounds=self.spec_rounds,
            spec_tokens=self.spec_tokens,
            acceptance_rate=(self.spec_accepted
                             / max(self.spec_proposed, 1)),
            spec_fallbacks=self.spec_fallbacks,
            disagg_handoffs=self.disagg_handoffs,
            disagg_colocated=self.disagg_colocated,
            disagg_fallbacks=self.disagg_fallbacks,
            kv_transfer_bytes=self.kv_transfer_bytes,
            kv_transfer_s=self.kv_transfer_s,
            per_clone=per_clone)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--policy", default="exec_time")
    ap.add_argument("--handler", action="store_true",
                    help="serve through the event-driven ClientHandler")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson offered load (req/s) for --handler")
    ap.add_argument("--kv", choices=["paged", "contiguous"], default="paged",
                    help="KV cache mode for --handler")
    ap.add_argument("--window", type=int, default=1,
                    help="decode window: tokens fused per device dispatch")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if args.handler:
        backend = LMBackend(cfg, capacity=64)
        handler = ClientHandler(backend, max_batch=args.batch, kv=args.kv,
                                decode_window=args.window)
        reqs = poisson_arrivals(args.rate, args.requests,
                                prompt_len=8, vocab=cfg.vocab_size,
                                max_new_tokens=args.new_tokens)
        report = handler.run(reqs, drain_idle_s=60.0)
        print(report.summary())
        print("pool:", report.pool_stats)
        return

    eng = ServingEngine(cfg, policy=Policy(args.policy))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=12,
                                    dtype=np.int32), args.new_tokens)
            for i in range(args.requests)]
    done = []
    for i in range(0, len(reqs), args.batch):
        comps = eng.serve_batch(reqs[i:i + args.batch])
        done.extend(comps)
        c = comps[0]
        print(f"batch {i // args.batch}: venue={c.prefill_venue} "
              f"latency={c.latency_s:.3f}s tokens={c.tokens[:6]}...")
    print("stats:", eng.stats)


if __name__ == "__main__":
    main()
