"""Serving stack: ThinkAir's Client Handler for LM inference.

Two layers share one model binding (``LMBackend``):

``ServingEngine`` — the batch-at-a-time path (seed behaviour).  Each request
batch is a remoteable method invocation: the ExecutionController decides
placement (local small venue vs cloud clones) per batch from profiled
history; long-context requests whose KV-cache working set exceeds the
default clone's memory are escalated to a bigger clone type (the paper's
OutOfMemoryError path); prefill for large batches can be split across k
clones (the paper's parallelization path).

``ClientHandler`` — the event-driven continuous-batching server (paper
§5.2-§5.3, the tentpole of the Client Handler refactor).  Requests arrive
on a shared :class:`~repro.core.clock.VirtualClock`, pass admission control
(:class:`~repro.core.scheduler.AdmissionQueue`), and are formed into
*cohorts* of up to ``max_batch`` requests.  Each cohort's prefill and every
decode step is a non-blocking :class:`~repro.core.dispatch.Dispatcher` task
on one clone, so cohorts on different clones genuinely overlap on the
timeline.  Requests **leave** their cohort at decode-step granularity the
moment they hit their token budget (the cohort's KV cache shrinks in
place), and new arrivals **enter** service at the next step boundary on any
free clone — they never wait for a whole batch to drain.  A queue-depth
driven :class:`~repro.core.scheduler.QueueAutoscaler` provisions and
TTL-pauses secondaries through the ClonePool lifecycle, which makes the
paper's elasticity claim measurable as p50/p99 latency and tokens/s under
Poisson offered load (see ``benchmarks/serving_load.py``).

Cohort fusion note: the decode cache keeps a single shared position cursor,
so only requests admitted at the same step boundary are fused into one
batched decode call; a late arrival starts its own cohort rather than
joining mid-flight (per-slot cursors / paged caches are future work).
Weights are resident on the clones (serving fleet), so per-request network
cost is prompt/token traffic only — unlike the offload path, which ships
the method's whole state.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import (ClonePool, ExecutionController, Policy,
                        RemoteableMethod)
from repro.core.clock import VirtualClock
from repro.core.dispatch import Dispatcher
from repro.core.scheduler import (AdmissionQueue, QueueAutoscaler,
                                  ServeCompletion, ServeRequest,
                                  poisson_arrivals)
from repro.core.venues import Venue, pytree_bytes, transfer_time
from repro.launch import steps as S
from repro.models import model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prefill_venue: str
    decode_venue: str
    latency_s: float
    escalations: int


class LMBackend:
    """Model binding: params + jitted prefill/decode + cache batch surgery."""

    def __init__(self, cfg, capacity: int = 256):
        self.cfg = cfg
        self.capacity = capacity
        self.ctx = S.make_context(None,
                                  moe_capacity_factor=(
                                      cfg.n_experts / cfg.top_k
                                      if cfg.is_moe else 1.25))
        self.params = model.init(cfg, jax.random.PRNGKey(0))
        cap = capacity

        def prefill_fn(params, tokens):
            logits, cache = model.forward(cfg, params, {"tokens": tokens},
                                          self.ctx, "prefill",
                                          cache_capacity=cap)
            return jnp.argmax(logits, -1), cache

        def decode_fn(params, cache, tokens, pos):
            logits, cache = model.decode_step(cfg, params, cache, tokens,
                                              pos, self.ctx)
            return jnp.argmax(logits, -1), cache

        self.prefill = jax.jit(prefill_fn)
        self.decode = jax.jit(decode_fn)
        # locate each cache leaf's batch axis by diffing abstract shapes
        a1 = model.abstract_cache(cfg, 1, cap)
        a2 = model.abstract_cache(cfg, 2, cap)

        def batch_axis(x, y):
            diff = [i for i, (p, q) in enumerate(zip(x.shape, y.shape))
                    if p != q]
            return diff[0] if diff else None

        self._batch_axis = jax.tree.map(batch_axis, a1, a2)

    def cache_mem_bytes(self, batch: int) -> int:
        return pytree_bytes(model.abstract_cache(self.cfg, batch,
                                                 self.capacity))

    def cache_take(self, cache, keep_idx) -> Dict:
        """Shrink a cohort cache to the surviving batch rows."""
        idx = jnp.asarray(np.asarray(keep_idx, np.int32))

        def take(leaf, ax):
            return leaf if ax is None else jnp.take(leaf, idx, axis=ax)

        return jax.tree.map(take, cache, self._batch_axis)


class ServingEngine:
    """Batched prefill + decode with ThinkAir placement decisions."""

    def __init__(self, cfg, *, policy: Policy = Policy.EXEC_TIME,
                 link: str = "wifi-local", max_batch: int = 8,
                 capacity: int = 256, backend: Optional[LMBackend] = None):
        self.cfg = cfg
        self.max_batch = max_batch
        self.capacity = capacity
        self.backend = backend or LMBackend(cfg, capacity)
        self.params = self.backend.params
        self.ec = ExecutionController(policy=policy, link=link)
        self.ec.pool.provision("main", 8)       # paused secondaries (paper)
        backend_ = self.backend

        # KV working set drives escalation: bytes ~ cache size
        def prefill_mem(params, tokens):
            return backend_.cache_mem_bytes(tokens.shape[0])

        self.rm_prefill = RemoteableMethod(
            "serve_prefill", self.backend.prefill, jit=False,
            size_fn=lambda p, t: t.size,
            split_fn=self._split_prefill, merge_fn=self._merge_prefill,
            mem_fn=prefill_mem)
        self.rm_decode = RemoteableMethod(
            "serve_decode", self.backend.decode, jit=False,
            size_fn=lambda p, c, t, pos: t.shape[0])
        self.stats = {"requests": 0, "batches": 0, "offloaded": 0,
                      "escalations": 0}

    @staticmethod
    def _split_prefill(args, k):
        params, tokens = args
        tok_shards = np.array_split(np.asarray(tokens), k, axis=0)
        return [(params, jnp.asarray(t)) for t in tok_shards]

    @staticmethod
    def _merge_prefill(values):
        toks = jnp.concatenate([v[0] for v in values], axis=0)
        caches = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                              *[v[1] for v in values])
        return toks, caches

    def serve_batch(self, reqs: List[Request], *, n_clones: int = 1,
                    force: Optional[str] = None) -> List[Completion]:
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :len(r.prompt)] = r.prompt
        res_p = self.ec.execute(self.rm_prefill, self.params,
                                jnp.asarray(toks), n_clones=n_clones,
                                force=force)
        next_tok, cache = res_p.value
        out = [list() for _ in reqs]
        steps_needed = max(r.max_new_tokens for r in reqs)
        tok = next_tok[:, None]
        total_time = res_p.time_s
        decode_venue = "-"
        # per-batch aggregation over prefill AND every decode step
        offloaded = int(res_p.offloaded)
        escalations = res_p.escalations
        for step_i in range(steps_needed):
            for i in range(len(reqs)):
                out[i].append(int(tok[i, 0]))
            pos = jnp.int32(min(plen + step_i, self.capacity - 1))
            res_d = self.ec.execute(self.rm_decode, self.params, cache, tok,
                                    pos, force=force)
            tok, cache = res_d.value
            tok = tok[:, None]
            total_time += res_d.time_s
            decode_venue = res_d.venue
            offloaded += int(res_d.offloaded)
            escalations += res_d.escalations
        self.stats["requests"] += len(reqs)
        self.stats["batches"] += 1
        self.stats["offloaded"] += offloaded
        self.stats["escalations"] += escalations
        return [Completion(r.rid, out[i], res_p.venue, decode_venue,
                           total_time, escalations)
                for i, r in enumerate(reqs)]


# --------------------------------------------------------------------------- #
# Event-driven Client Handler (continuous batching + elastic clones)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _Cohort:
    """Requests admitted at one step boundary, decoding in lockstep."""

    reqs: List[ServeRequest]
    clone: object
    plen: int
    outs: List[List[int]] = dataclasses.field(default_factory=list)
    first_token_t: List[float] = dataclasses.field(default_factory=list)
    cache: object = None
    tok: object = None
    step: int = 0
    phase: str = "prefill"


@dataclasses.dataclass
class ServeReport:
    completions: List[ServeCompletion]
    accepted: int
    rejected: int
    makespan_s: float
    p50_latency_s: float
    p99_latency_s: float
    p50_ttft_s: float
    tokens_per_s: float
    peak_secondaries: int
    scale_ups: int
    busy_energy_j: float
    pool_stats: Dict
    clone_samples: List[tuple]

    def summary(self) -> str:
        return (f"served={len(self.completions)} shed={self.rejected} "
                f"p50={self.p50_latency_s:.3f}s p99={self.p99_latency_s:.3f}s "
                f"tok/s={self.tokens_per_s:.1f} "
                f"peak_secondaries={self.peak_secondaries}")


class ClientHandler:
    """Event-driven continuous-batching server on an elastic clone pool."""

    def __init__(self, backend, *, link: str = "wifi-local",
                 clone_type: str = "main", max_batch: int = 4,
                 queue_depth: int = 64, max_secondaries: int = 8,
                 min_secondaries: int = 0, work_per_clone: int = 1,
                 prompt_pad: int = 8, use_primary: bool = True,
                 provision_paused: bool = True,
                 executor: Optional[Callable] = None,
                 pool: Optional[ClonePool] = None,
                 clock: Optional[VirtualClock] = None):
        self.backend = backend
        # one timeline: adopt a supplied pool's clock (TTL accounting and
        # dispatch must share it), otherwise build pool around ours
        if pool is not None:
            if not getattr(pool.clock, "virtual", False):
                raise TypeError("ClientHandler needs a pool on a "
                                "VirtualClock")
            if clock is not None and clock is not pool.clock:
                raise ValueError("pool and clock disagree — pass one "
                                 "timeline")
            self.clock = pool.clock
            self.pool = pool
        else:
            self.clock = clock or VirtualClock()
            self.pool = ClonePool(link_name=link, clock=self.clock,
                                  max_clones=max_secondaries + 8)
        self.dispatcher = Dispatcher(self.pool, self.clock)
        self.queue = AdmissionQueue(queue_depth)
        self.autoscaler = QueueAutoscaler(
            self.pool, clone_type=clone_type, work_per_clone=work_per_clone,
            min_secondaries=min_secondaries, max_secondaries=max_secondaries)
        if provision_paused:     # paper §5.3: secondaries pre-created paused
            self.pool.provision(clone_type, max_secondaries)
        self.clone_type = clone_type
        self.max_batch = max_batch
        self.prompt_pad = prompt_pad
        self.use_primary = use_primary
        if not use_primary and max_secondaries < 1:
            raise ValueError("no primary and no secondaries: nothing can run")
        # executor(clone, fn, args) -> (value, venue_seconds); the default
        # runs on the clone's venue spec (tests inject fixed venue times)
        if executor is None:
            def executor(clone, fn, args):
                return Venue(clone.spec).execute(fn, *args)
        self.executor = executor
        self.busy_energy_j = 0.0
        self.tokens_emitted = 0

    # ---------------------------------------------------------------- clones
    def _free_clone(self):
        """Cheapest usable clone: warm first, then provisioning ones."""
        now = self.clock.now()
        cands = []
        if self.use_primary and not self.pool.primary.busy:
            cands.append((0.0, 0, self.pool.primary))
        for c in self.pool.running_secondaries(self.clone_type):
            if not c.busy:
                cands.append((self.autoscaler.clone_ready_delay(c, now),
                              c.cid, c))
        return min(cands)[2] if cands else None

    def _net_s(self, nbytes: int) -> float:
        return transfer_time(nbytes, self.pool.link)

    # ---------------------------------------------------------------- cohort
    def _start_cohort(self, batch: List[ServeRequest], clone):
        plen = self.prompt_pad
        toks = np.zeros((len(batch), plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, :min(len(r.prompt), plen)] = r.prompt[:plen]
        cohort = _Cohort(reqs=batch, clone=clone, plen=plen,
                         outs=[[] for _ in batch],
                         first_token_t=[0.0] * len(batch))
        clone.busy = True
        delay = (self.autoscaler.clone_ready_delay(clone, self.clock.now())
                 + self._net_s(toks.nbytes))
        task = self.dispatcher.submit(
            clone, self.backend.prefill, (self.backend.params,
                                          jnp.asarray(toks)),
            executor=self.executor, extra_delay=delay, label="prefill")
        self.busy_energy_j += task.venue_seconds * clone.spec.power_peak
        return task, cohort

    def _submit_decode(self, cohort: _Cohort):
        pos = jnp.int32(min(cohort.plen + cohort.step,
                            self.backend.capacity - 1))
        task = self.dispatcher.submit(
            cohort.clone, self.backend.decode,
            (self.backend.params, cohort.cache, cohort.tok, pos),
            executor=self.executor,
            extra_delay=self._net_s(len(cohort.reqs) * 8), label="decode")
        self.busy_energy_j += task.venue_seconds * cohort.clone.spec.power_peak
        return task

    def _retire(self, cohort: _Cohort, completions: List[ServeCompletion]
                ) -> bool:
        """Emit current tokens; drop finished rows.  True while alive."""
        now = self.clock.now()
        tok = np.asarray(cohort.tok)[:, 0]
        keep = []
        for i, r in enumerate(cohort.reqs):
            cohort.outs[i].append(int(tok[i]))
            if len(cohort.outs[i]) == 1:
                cohort.first_token_t[i] = now
            if len(cohort.outs[i]) >= r.max_new_tokens:
                self.tokens_emitted += len(cohort.outs[i])
                completions.append(ServeCompletion(
                    r.rid, cohort.outs[i], r.arrival_t,
                    cohort.first_token_t[i], now, cohort.clone.spec.name))
            else:
                keep.append(i)
        if not keep:
            self.pool.release([cohort.clone])
            return False
        if len(keep) < len(cohort.reqs):      # leave at step granularity
            cohort.reqs = [cohort.reqs[i] for i in keep]
            cohort.outs = [cohort.outs[i] for i in keep]
            cohort.first_token_t = [cohort.first_token_t[i] for i in keep]
            cohort.tok = cohort.tok[np.asarray(keep, np.int32)]
            cohort.cache = self.backend.cache_take(cohort.cache, keep)
        return True

    # ------------------------------------------------------------------ run
    def run(self, requests: List[ServeRequest], *,
            drain_idle_s: float = 0.0) -> ServeReport:
        reqs = sorted(requests, key=lambda r: r.arrival_t)
        t_start = self.clock.now()
        i = 0
        inflight: Dict[object, _Cohort] = {}
        completions: List[ServeCompletion] = []

        while True:
            now = self.clock.now()
            while i < len(reqs) and reqs[i].arrival_t <= now + 1e-12:
                self.queue.offer(reqs[i], now)
                i += 1
            # demand in cohort units: queued requests coalesce into batches
            queued_cohorts = -(-self.queue.depth // self.max_batch)
            self.autoscaler.step(now, queued_cohorts, len(inflight))
            # form cohorts while a clone is free (join at step boundaries)
            while self.queue.depth > 0:
                clone = self._free_clone()
                if clone is None:
                    break
                task, cohort = self._start_cohort(
                    self.queue.take(self.max_batch), clone)
                inflight[task] = cohort

            if inflight:
                # bound the wait so due arrivals are admitted on time
                next_arrival = reqs[i].arrival_t if i < len(reqs) else None
                first_done = min(t.done_at for t in inflight)
                if next_arrival is not None and next_arrival < first_done:
                    self.clock.advance_to(next_arrival)
                    continue
                for task in self.dispatcher.wait_any(list(inflight)):
                    cohort = inflight.pop(task)
                    if cohort.phase == "prefill":
                        tok, cohort.cache = task.value
                        cohort.tok = tok[:, None]
                        cohort.phase = "decode"
                    else:
                        tok, cohort.cache = task.value
                        cohort.tok = tok[:, None]
                        cohort.step += 1
                    if self._retire(cohort, completions):
                        inflight[self._submit_decode(cohort)] = cohort
            elif i < len(reqs):
                self.clock.advance_to(reqs[i].arrival_t)
            elif self.queue.depth > 0:
                raise RuntimeError("requests queued but no clone can run "
                                   "(max_secondaries too small?)")
            else:
                break

        if drain_idle_s > 0.0:       # let idle TTLs pause the secondaries
            self.clock.advance(drain_idle_s)
            self.autoscaler.step(self.clock.now(), 0, 0)

        lat = np.array([c.latency_s for c in completions]) \
            if completions else np.zeros(1)
        ttft = np.array([c.ttft_s for c in completions]) \
            if completions else np.zeros(1)
        makespan = self.clock.now() - t_start - drain_idle_s
        return ServeReport(
            completions=completions,
            accepted=self.queue.accepted,
            rejected=self.queue.rejected,
            makespan_s=makespan,
            p50_latency_s=float(np.percentile(lat, 50)),
            p99_latency_s=float(np.percentile(lat, 99)),
            p50_ttft_s=float(np.percentile(ttft, 50)),
            tokens_per_s=self.tokens_emitted / max(makespan, 1e-9),
            peak_secondaries=self.autoscaler.peak_secondaries,
            scale_ups=self.autoscaler.scale_ups,
            busy_energy_j=self.busy_energy_j,
            pool_stats=dict(self.pool.stats),
            clone_samples=list(self.autoscaler.samples))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--policy", default="exec_time")
    ap.add_argument("--handler", action="store_true",
                    help="serve through the event-driven ClientHandler")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="Poisson offered load (req/s) for --handler")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if args.handler:
        backend = LMBackend(cfg, capacity=64)
        handler = ClientHandler(backend, max_batch=args.batch)
        reqs = poisson_arrivals(args.rate, args.requests,
                                prompt_len=8, vocab=cfg.vocab_size,
                                max_new_tokens=args.new_tokens)
        report = handler.run(reqs, drain_idle_s=60.0)
        print(report.summary())
        print("pool:", report.pool_stats)
        return

    eng = ServingEngine(cfg, policy=Policy(args.policy))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=12,
                                    dtype=np.int32), args.new_tokens)
            for i in range(args.requests)]
    done = []
    for i in range(0, len(reqs), args.batch):
        comps = eng.serve_batch(reqs[i:i + args.batch])
        done.extend(comps)
        c = comps[0]
        print(f"batch {i // args.batch}: venue={c.prefill_venue} "
              f"latency={c.latency_s:.3f}s tokens={c.tokens[:6]}...")
    print("stats:", eng.stats)


if __name__ == "__main__":
    main()
