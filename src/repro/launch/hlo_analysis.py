"""HLO-level analysis: collective-byte accounting + cost extraction.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled per-device HLO and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device *operand* bytes per collective kind.

    XLA's printer omits operand shapes, so we derive operand size from the
    RESULT shape + the op semantics (per-shard group size parsed from
    replica_groups=[n_groups, group_size]):
      all-gather:      operand = result / group_size
      reduce-scatter:  operand = result * group_size
      all-reduce / all-to-all / collective-permute: operand = result
    Loop bodies are counted once — callers correct trip counts via the
    unrolled-analysis pass.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        m = re.search(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(", rhs)
        if not m:
            continue
        kind = m.group(1)
        # result shape(s): dtype[dims] tokens before the op name
        head = rhs[:m.start()]
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(head))
        gm = _GROUPS_RE.search(rhs)
        gsize = int(gm.group(2)) if gm else 1
        if kind == "all-gather":
            nbytes = result_bytes // max(gsize, 1)
        elif kind == "reduce-scatter":
            nbytes = result_bytes * gsize
        else:
            nbytes = result_bytes
        out[kind] += nbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def cost_metrics(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def memory_metrics(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes
                          + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes
                          - ma.alias_size_in_bytes),
    }
