import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --------------------------------------------------------------------------- #
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
# ShapeDtypeStruct inputs (zero allocation), print memory_analysis() and
# cost_analysis(), parse collective bytes, and write a JSON record per cell.
#
# The 512 placeholder host devices exist ONLY here (the two lines above run
# before any other import, since jax locks the device count on first init).
# --------------------------------------------------------------------------- #
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (SHAPES, applicability, get_config,  # noqa: E402
                           get_shape, list_archs)
from repro.distributed import sharding as shd                  # noqa: E402
from repro.launch import hlo_analysis, steps                   # noqa: E402
from repro.launch.inputs import cache_capacity, input_specs    # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models import model                                 # noqa: E402
from repro.models.context import RunContext                    # noqa: E402
from repro.optim.adamw import OptConfig                        # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _ctx_kwargs(args) -> dict:
    kw = {}
    if args and getattr(args, "remat", None):
        kw["remat"] = args.remat
    if args and getattr(args, "loss_chunk", 0):
        kw["loss_chunk"] = args.loss_chunk
    if args and getattr(args, "microbatches", 0):
        kw["microbatches"] = args.microbatches
    if args and getattr(args, "profile", None):
        kw["sharding_profile"] = args.profile
    return kw


def lower_cell(cfg, shape, mesh, ctx):
    """Build the step fn for this cell and lower it on the mesh."""
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        step = steps.build_train_step(cfg, OptConfig(), ctx)
        state_abs = steps.abstract_state(cfg)
        state_sh = steps.state_shardings(cfg, mesh, ctx.sharding_profile)
        batch_sh = shd.input_shardings(specs["batch"], mesh,
                                       shape.global_batch)
        jf = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
        return jf.lower(state_abs, specs["batch"])
    if shape.kind == "prefill":
        step = steps.build_prefill_step(cfg, ctx)
        params_abs = model.init_abstract(cfg)
        params_sh = steps.param_shardings(cfg, mesh, ctx.sharding_profile)
        batch_sh = shd.input_shardings(specs["batch"], mesh,
                                       shape.global_batch)
        cache_sh = steps.cache_shardings(cfg, mesh, shape.global_batch,
                                         cache_capacity(cfg, shape.seq_len),
                                         ctx.sharding_profile)
        logits_sh = NamedSharding(
            mesh, P(shd.batch_spec(2, mesh)[0]
                    if shape.global_batch % _dp_size(mesh) == 0 else None,
                    None))
        jf = jax.jit(step, in_shardings=(params_sh, batch_sh),
                     out_shardings=(logits_sh, cache_sh))
        return jf.lower(params_abs, specs["batch"])
    # decode
    step = steps.build_decode_step(cfg, ctx)
    params_abs = model.init_abstract(cfg)
    params_sh = steps.param_shardings(cfg, mesh, ctx.sharding_profile)
    cache_sh = steps.cache_shardings(cfg, mesh, shape.global_batch,
                                     cache_capacity(cfg, shape.seq_len),
                                     ctx.sharding_profile)
    dp_ok = shape.global_batch % _dp_size(mesh) == 0
    tok_sh = NamedSharding(
        mesh, P(shd.batch_spec(2, mesh)[0] if dp_ok else None, None))
    logits_sh = NamedSharding(
        mesh, P(shd.batch_spec(2, mesh)[0] if dp_ok else None, None))
    jf = jax.jit(step,
                 in_shardings=(params_sh, cache_sh, tok_sh,
                               NamedSharding(mesh, P())),
                 out_shardings=(logits_sh, cache_sh),
                 donate_argnums=(1,))
    return jf.lower(params_abs, specs["cache"], specs["tokens"], specs["pos"])


def _dp_size(mesh) -> int:
    n = 1
    for a in shd.batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def _analysis_cfg(cfg, k: int):
    """Reduced-depth same-width config: k full pattern groups + same rest."""
    pat_len = len(cfg.block_pattern)
    rest = cfg.n_layers % pat_len
    return dataclasses.replace(cfg, n_layers=k * pat_len + rest)


def analyze_cell(arch: str, shape_name: str, multi_pod: bool,
                 args=None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind}
    ok, reason = applicability(cfg, shape)
    if not ok:
        record.update(status="skip", reason=reason)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = steps.make_context(mesh, **_ctx_kwargs(args))
    n_dev = mesh.size
    pat_len = len(cfg.block_pattern)
    n_groups = cfg.n_layers // pat_len

    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, ctx)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = hlo_analysis.memory_metrics(compiled)
    cost = hlo_analysis.cost_metrics(compiled)
    coll = hlo_analysis.collective_bytes(compiled.as_text())

    # ---- per-group cost via unrolled reduced-depth lowerings --------------
    # cost_analysis counts while-loop bodies once; lower k=1 and k=2 groups
    # fully unrolled, then total = outside + n_groups * per_group.
    corrected = {}
    if args is not None and getattr(args, "no_analysis", False):
        record.update(status="ok", n_devices=n_dev,
                      n_params=model.n_params(cfg),
                      n_active_params=model.n_active_params(cfg),
                      lower_seconds=round(t_lower, 2),
                      compile_seconds=round(t_compile, 2), memory=mem,
                      fits_hbm=mem["peak_bytes"] <= 16 * 1024 ** 3,
                      cost_reported=cost, collectives_reported=coll,
                      corrected={"skipped": True})
        return record
    try:
        ctx_u = dataclasses.replace(ctx, scan_unroll=True)
        c = {}
        for k in (1, 2):
            cfg_k = _analysis_cfg(cfg, k)
            comp_k = lower_cell(cfg_k, shape, mesh, ctx_u).compile()
            c[k] = {**hlo_analysis.cost_metrics(comp_k),
                    "coll": hlo_analysis.collective_bytes(
                        comp_k.as_text())["total"]}
        for key in ("flops", "bytes_accessed"):
            body = c[2][key] - c[1][key]
            corrected[key] = c[1][key] + (n_groups - 1) * body
        body_coll = c[2]["coll"] - c[1]["coll"]
        corrected["collective_bytes"] = c[1]["coll"] + \
            (n_groups - 1) * body_coll
        corrected["per_group_flops"] = c[2]["flops"] - c[1]["flops"]
    except Exception as e:                                   # noqa: BLE001
        corrected = {"error": f"{type(e).__name__}: {e}"}

    record.update(
        status="ok",
        n_devices=n_dev,
        n_params=model.n_params(cfg),
        n_active_params=model.n_active_params(cfg),
        lower_seconds=round(t_lower, 2),
        compile_seconds=round(t_compile, 2),
        memory=mem,
        fits_hbm=mem["peak_bytes"] <= 16 * 1024 ** 3,
        cost_reported=cost,
        collectives_reported=coll,
        corrected=corrected,
    )
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--no-analysis", action="store_true",
                    help="skip the k=1/k=2 unrolled cost-correction pass")
    ap.add_argument("--profile", default=None, choices=["tp", "zero-sp", "serve", "legacy"])
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = sorted(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                cell = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
                if args.tag:
                    cell += f"__{args.tag}"
                try:
                    rec = analyze_cell(arch, shape_name, mp, args)
                except Exception:                            # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": traceback.format_exc(limit=3)}
                    failures += 1
                with open(os.path.join(args.out, cell + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    gib = rec["memory"]["peak_bytes"] / 2 ** 30
                    extra = (f" peak={gib:.2f}GiB/dev"
                             f" compile={rec['compile_seconds']}s"
                             f" flops/dev={rec['corrected'].get('flops', 0):.3e}")
                    print(f"[{status}] {cell}{extra}")
                    print("  memory_analysis:", rec["memory"])
                    print("  cost_analysis:", rec["cost_reported"])
                elif status == "skip":
                    print(f"[skip] {cell}: {rec['reason']}")
                else:
                    print(f"[ERROR] {cell}\n{rec['error']}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
