"""Gradient compression with error feedback (fleet distributed-opt trick).

int8 quantization with per-tensor scale + error-feedback residuals: the
cross-replica gradient reduction moves 1 byte/param instead of 4 (or 2),
cutting the pod-axis collective roofline term ~4x, while error feedback
keeps convergence (residual carried into the next step).

Used by the manual-collective (shard_map) DP trainer in
``repro.launch.train``; the GSPMD path keeps XLA's native all-reduce.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def kv_quant_axes(ndim: int) -> Tuple[int, ...]:
    """Reduction axes for per-(block, head) KV scales.

    Gathered KV blocks are laid out ``(n_blocks, block_size, n_kv_heads,
    head_dim)`` (pool layout with the block axis moved to 0); the scale
    must survive per block AND per head, so reduce every axis except 0
    and the head axis at -2.  Leaves too small to carry a head axis
    (ndim < 3) fall back to per-block scales.
    """
    if ndim >= 3:
        return tuple(i for i in range(1, ndim) if i != ndim - 2)
    return tuple(range(1, ndim))


def quantize_kv_blocks(blocks: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(block, head) int8 quantization of gathered KV blocks.

    ``blocks``: (n, ...) gathered along the block axis (head axis at -2
    when present).  Returns ``(q int8, scales float32)`` with ``scales``
    keepdims-shaped so it broadcasts against ``blocks`` — the compressed
    KV transfer ships 1 byte/element plus one float32 scale per
    (block, head) instead of the full-width payload (ADR-009).
    """
    v = blocks.astype(jnp.float32)
    amax = jnp.max(jnp.abs(v), axis=kv_quant_axes(blocks.ndim),
                   keepdims=True)
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv_blocks(q: jax.Array, scale: jax.Array,
                         dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of :func:`quantize_kv_blocks` back to the pool dtype."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(g: jax.Array, residual: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    Wire bytes: 1 per element (int8 all-gather) + 4 per shard (scales),
    vs 4 per element for the fp32 psum it replaces.
    """
    v = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(v)
    new_residual = v - dequantize_int8(q, scale)
    # semantics of an int8 ring all-reduce: gather peers' int8 shards +
    # their scales, sum dequantized
    qs = jax.lax.all_gather(q, axis_name)            # (k, ...)
    scales = jax.lax.all_gather(scale, axis_name)    # (k,)
    summed = jnp.tensordot(scales,
                           qs.astype(jnp.float32), axes=((0,), (0,)))
    k = qs.shape[0]
    return summed / k, new_residual


def tree_compressed_pmean(grads, residuals, axis_name: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [compressed_psum(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
