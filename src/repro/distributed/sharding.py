"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §4).

Divisibility-aware: a logical dim whose size does not divide its preferred
mesh axis falls back to replication (e.g. Mixtral's 8 experts on a 16-wide
model axis -> expert dim replicated, d_ff takes the model axis instead via
the "mlp" rule).  A mesh axis is used at most once per tensor.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map_compat(body, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (replication checks off).

    jax >= 0.6 exposes it as ``jax.shard_map(check_vma=...)``; older
    releases as ``jax.experimental.shard_map.shard_map(check_rep=...)``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)

# logical axis -> preferred mesh axis / tuple of axes (None = replicated)
DEFAULT_RULES: Dict[str, object] = {
    # data-parallel dims
    "batch": ("pod", "data"),
    # tensor-parallel dims
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "lru": "model",
    "rank": "model",
    # KV-cache sequence dim: takes the model axis when kv_heads can't
    # (flash-decoding style partial-softmax sharding; see §Perf H2)
    "kv_seq": "model",
    # FSDP dim
    "embed": "data",
    "lru_in": "data",
    # replicated
    "head_dim": None,
    "conv": None,
    "layers": None,
    "embed_out": None,
    "experts_r": None,
}

# allocation priority: earlier entries claim mesh axes first (a tensor's
# dims are assigned in this order, then the spec is emitted in dim order)
_PRIORITY = ["batch", "vocab", "heads", "kv_heads", "mlp", "experts", "lru",
             "rank", "kv_seq", "embed", "lru_in"]

# ZeRO-SP profile (§Perf H3): weights FSDP-only (gathered per layer), the
# model axis carries the sequence — cuts Megatron activation all-reduces
ZERO_SP_RULES: Dict[str, object] = dict(
    DEFAULT_RULES,
    heads=None, kv_heads=None, mlp=None, lru=None, rank=None,
)


def abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """Version-portable ``jax.sharding.AbstractMesh`` constructor.

    JAX changed the signature across releases: older versions take
    ``(axis_sizes, axis_names)``, 0.4.36+ takes a single tuple of
    ``(name, size)`` pairs.  ``spec_for`` only needs ``mesh.shape``
    (name -> size), which both spellings provide.
    """
    sizes, names = tuple(axis_sizes), tuple(axis_names)
    try:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return jax.sharding.AbstractMesh(sizes, names)


# serve profile (§Perf H2b): params resident (model-axis TP dims only, no
# FSDP dim) — eliminates per-step weight gathers on the decode path
SERVE_RULES: Dict[str, object] = dict(
    DEFAULT_RULES, embed=None, lru_in=None,
)


# pre-hillclimb baseline: no kv-cache sequence sharding (EXPERIMENTS §Perf)
LEGACY_RULES: Dict[str, object] = dict(DEFAULT_RULES, kv_seq=None)


def rules_for(profile: str) -> Dict[str, object]:
    if profile == "zero-sp":
        return ZERO_SP_RULES
    if profile == "serve":
        return SERVE_RULES
    if profile == "legacy":
        return LEGACY_RULES
    return DEFAULT_RULES

# batch dims shard over the pure-DP axes (pod + data)
def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]], mesh: Mesh,
             rules: Dict[str, Optional[str]] = None) -> P:
    """PartitionSpec for one tensor from its logical axes."""
    rules = rules or DEFAULT_RULES
    used = set()
    out = [None] * len(axes)
    order = sorted(range(len(axes)),
                   key=lambda i: _PRIORITY.index(axes[i])
                   if axes[i] in _PRIORITY else len(_PRIORITY))
    for i in order:
        size, logical = shape[i], axes[i]
        pref = rules.get(logical) if logical is not None else None
        if pref is None:
            continue
        cand = tuple(a for a in (pref if isinstance(pref, tuple) else (pref,))
                     if a in mesh.shape and a not in used)
        total = 1
        for a in cand:
            total *= mesh.shape[a]
        if not cand or size % total != 0:
            continue
        out[i] = cand if len(cand) > 1 else cand[0]
        used.update(cand)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(abstract_tree, axes_tree, mesh: Mesh, rules=None):
    """PartitionSpec pytree matching an abstract (ShapeDtypeStruct) tree."""
    return jax.tree.map(
        lambda leaf, axes: spec_for(leaf.shape, axes, mesh, rules),
        abstract_tree, axes_tree)


def tree_shardings(abstract_tree, axes_tree, mesh: Mesh, rules=None):
    specs = tree_specs(abstract_tree, axes_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(ndim: int, mesh: Mesh) -> P:
    """Inputs: leading batch dim over (pod, data)."""
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(dp, *([None] * (ndim - 1)))


def input_shardings(batch_tree, mesh: Mesh, global_batch: int):
    """Shardings for an input batch pytree; replicates non-divisible batches
    (long_500k batch=1)."""
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def f(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim >= 1 \
                and leaf.shape[0] == global_batch \
                and global_batch % dp_size == 0:
            return NamedSharding(mesh, batch_spec(leaf.ndim, mesh))
        return NamedSharding(mesh, P())

    return jax.tree.map(f, batch_tree)
