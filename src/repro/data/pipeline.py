"""Deterministic synthetic data pipeline.

Counter-based PRNG keyed by (seed, step): restart/elastic-resize resume is a
pure function of the step number — no iterator state to checkpoint, and any
data-parallel worker can regenerate any shard (fleet requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 1234


class Pipeline:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg

    def _key(self, step: int) -> jax.Array:
        return jax.random.fold_in(jax.random.PRNGKey(self.dcfg.seed), step)

    def batch(self, step: int) -> Dict[str, jax.Array]:
        """The full global batch for one step (host-resident)."""
        cfg, d = self.cfg, self.dcfg
        key = self._key(step)
        b, s = d.global_batch, d.seq_len
        k1, k2, k3 = jax.random.split(key, 3)
        if cfg.frontend == "audio":
            frames = jax.random.normal(k1, (b, s, cfg.d_model), jnp.float32)
            targets = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
            mask = jax.random.bernoulli(k3, 0.08, (b, s))  # HuBERT-style
            return {"frames": frames, "targets": targets,
                    "loss_mask": mask.astype(jnp.float32)}
        if cfg.frontend == "vision":
            p = cfg.n_patches
            patches = jax.random.normal(k1, (b, p, cfg.d_model), jnp.float32)
            toks = jax.random.randint(k2, (b, s - p + 1), 0, cfg.vocab_size)
            return {"patches": patches, "tokens": toks[:, :-1],
                    "targets": toks[:, 1:]}
        toks = jax.random.randint(k1, (b, s + 1), 0, cfg.vocab_size)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def abstract_batch(self, dtype=jnp.float32):
        return jax.eval_shape(lambda: self.batch(0))
