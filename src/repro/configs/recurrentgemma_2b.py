"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (GQA kv=1, head_dim=256) d_ff=7680 vocab=256000
[arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    window=2048,                       # local attention window
    block_pattern=("rglru", "rglru", "attn"),
    mlp_act="gelu",                    # GeGLU as in gemma
    mlp_gated=True,
    norm_type="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
    final_softcap=30.0,
    rope_theta=10_000.0,
    sub_quadratic=True,                # O(1) LRU state + windowed attention
)
