"""olmoe-1b-7b [moe] — 64 experts, top-8 routing.

16L d_model=2048 16H (kv=16, head_dim=128) d_ff=1024 (per expert)
vocab=50304, MoE 64e top-8 [arXiv:2409.02060]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,                         # per-expert hidden width
    vocab_size=50_304,
    n_experts=64,
    top_k=8,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="rmsnorm",
    sub_quadratic=False,
)
