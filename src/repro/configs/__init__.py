"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    applicability,
)

from repro.configs import (  # noqa: E402
    hubert_xlarge,
    mixtral_8x7b,
    olmoe_1b_7b,
    paligemma_3b,
    phi3_mini_3_8b,
    qwen2_5_3b,
    recurrentgemma_2b,
    rwkv6_7b,
    smollm_360m,
    stablelm_1_6b,
)

_REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        recurrentgemma_2b,
        hubert_xlarge,
        smollm_360m,
        stablelm_1_6b,
        qwen2_5_3b,
        phi3_mini_3_8b,
        olmoe_1b_7b,
        mixtral_8x7b,
        rwkv6_7b,
        paligemma_3b,
    )
}


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return _REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests.

    Preserves structure (GQA ratio, pattern, gating, MoE routing, frontend)
    while shrinking every capacity dimension.
    """
    q_per_kv = cfg.q_per_kv
    n_heads = min(cfg.n_heads, 2 * q_per_kv)
    n_heads = max(n_heads - n_heads % q_per_kv, q_per_kv)
    updates = dict(
        name=cfg.name + "-smoke",
        n_layers=max(len(cfg.block_pattern), 2),
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=max(1, n_heads // q_per_kv),
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        window=min(cfg.window, 8) if cfg.window else None,
        n_patches=4,
        conv1d_width=cfg.conv1d_width,
        dtype="float32",
    )
    if cfg.family == "ssm":
        # rwkv heads span d_model exactly: d_model = n_heads * head_dim
        updates["n_heads"] = 4
        updates["n_kv_heads"] = 4
        updates["head_dim"] = 16
    return dataclasses.replace(cfg, **updates)


def draft_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced-cost draft config for cross-tier speculative decoding.

    The draft model shares the target's tokenizer/vocab (acceptance
    compares token ids directly) and its structural family, but shrinks
    every capacity dimension well below even the smoke config: the point
    is a per-step cost an order of magnitude under the target's, so the
    cheap fleet tier can propose K tokens for one large-tier
    verification (docs/architecture.md ADR-008).  ``head_dim`` is kept
    at the smoke size so rope tables and the paged block geometry stay
    shared with the target pool's block tables.
    """
    base = reduced_config(cfg)
    updates = dict(
        name=cfg.name + "-draft",
        n_layers=2,
        d_model=32,
        d_ff=48,
        n_experts=min(base.n_experts, 2),
        top_k=min(base.top_k, 1),
    )
    if cfg.family == "ssm":
        updates["n_heads"] = 2
        updates["n_kv_heads"] = 2
        updates["d_model"] = 32
    else:
        q_per_kv = base.q_per_kv
        updates["n_heads"] = q_per_kv
        updates["n_kv_heads"] = 1
    return dataclasses.replace(base, **updates)


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "applicability",
    "get_config",
    "get_shape",
    "list_archs",
    "reduced_config",
    "draft_config",
]
