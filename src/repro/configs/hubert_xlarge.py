"""hubert-xlarge [audio] — encoder-only transformer backbone.

48L d_model=1280 16H (kv=16, head_dim=80) d_ff=5120 vocab=504
[arXiv:2106.07447].  The audio frontend (CNN feature extractor) is a STUB:
``input_specs()`` provides precomputed frame embeddings (batch, seq, d_model).
Deviation noted in DESIGN.md: rotary positions replace the conv positional
embedding of the original (frontend-stub assignment).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,                    # masked-prediction codebook
    causal=False,
    encoder_only=True,
    mlp_act="gelu",
    mlp_gated=False,
    norm_type="layernorm",
    frontend="audio",
    sub_quadratic=False,
)
