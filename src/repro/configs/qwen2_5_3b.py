"""qwen2.5-3b [dense] — GQA with QKV bias.

36L d_model=2048 16H (GQA kv=2, head_dim=128) d_ff=11008 vocab=151936
[hf:Qwen/Qwen2.5-3B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11_008,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="rmsnorm",
    tie_embeddings=True,
    sub_quadratic=False,
)
