"""paligemma-3b [vlm] — SigLIP frontend (STUB) + gemma decoder backbone.

18L d_model=2048 8H (GQA kv=1, head_dim=256) d_ff=16384 vocab=257216
[arXiv:2407.07726].  The SigLIP vision tower is a STUB per assignment:
``input_specs()`` provides precomputed patch embeddings (batch, 256, d_model);
the image prefix uses bidirectional (prefix-LM) attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    mlp_act="gelu",                    # GeGLU
    mlp_gated=True,
    norm_type="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
    frontend="vision",
    n_patches=256,
    sub_quadratic=False,
)
