"""rwkv6-7b [ssm] — "Finch": attention-free, data-dependent decay.

32L d_model=4096 (attn-free; 64 wkv heads of dim 64) d_ff=14336 vocab=65536
[arXiv:2404.05892]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                        # wkv heads (head_dim 64)
    n_kv_heads=64,
    head_dim=64,
    d_ff=14_336,
    vocab_size=65_536,
    block_pattern=("rwkv",),
    mlp_act="relu",                    # channel-mix uses relu^2
    mlp_gated=False,
    norm_type="layernorm",
    sub_quadratic=True,                # O(1) recurrent state
)
