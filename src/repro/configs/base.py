"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; every assigned
input shape as a ``ShapeConfig``.  The (arch x shape) grid is resolved by
``applicability`` which encodes the skip rules from DESIGN.md
(section "Arch-applicability").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified configuration covering dense / MoE / SSM / hybrid / audio / VLM."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    window: Optional[int] = None     # sliding-window size (None = full attention)
    logit_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True
    encoder_only: bool = False

    # --- mlp ---
    mlp_act: str = "silu"            # silu | gelu
    mlp_gated: bool = True           # SwiGLU/GeGLU vs plain 2-layer MLP

    # --- mixture of experts ---
    n_experts: int = 0
    top_k: int = 0

    # --- layer pattern (tiled to n_layers); entries: attn | rglru | rwkv ---
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- recurrent blocks ---
    conv1d_width: int = 4            # temporal conv in RG-LRU block

    # --- norms / embeddings ---
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma-style sqrt(d) embedding scale

    # --- modality frontend stub ---
    frontend: Optional[str] = None   # None | audio | vision
    n_patches: int = 256             # VLM image-prefix length

    # --- numerics / long-context eligibility ---
    dtype: str = "bfloat16"
    sub_quadratic: bool = False      # eligible for long_500k decode

    def layer_kinds(self) -> Tuple[str, ...]:
        """The per-layer block kind, pattern tiled/truncated to n_layers."""
        pat = self.block_pattern
        reps = -(-self.n_layers // len(pat))
        return tuple((pat * reps)[: self.n_layers])

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned input shape.  ``kind`` selects which step fn is lowered."""

    name: str
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicability(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason).  Encodes DESIGN.md skip rules."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k quadratic decode skipped"
    return True, "ok"
