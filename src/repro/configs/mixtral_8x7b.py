"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=14336 (per expert)
vocab=32000, MoE 8e top-2, SWA window 4096 [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    n_experts=8,
    top_k=2,
    window=4096,                       # sliding-window attention, rolling cache
    mlp_act="silu",
    mlp_gated=True,
    norm_type="rmsnorm",
    sub_quadratic=True,                # SWA => O(window) decode cache
)
