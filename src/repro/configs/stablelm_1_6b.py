"""stablelm-1.6b [dense].

24L d_model=2048 32H (kv=32, head_dim=64) d_ff=5632 vocab=100352
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100_352,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="layernorm",             # stablelm-2 uses LayerNorm
    sub_quadratic=False,
)
