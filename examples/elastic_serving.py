"""Serve a small LM with ThinkAir placement, escalation and clone elasticity.

    PYTHONPATH=src python examples/elastic_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                        # noqa: E402

from repro.configs import get_config, reduced_config      # noqa: E402
from repro.core import Policy, poisson_arrivals           # noqa: E402
from repro.launch.serve import (ClientHandler, LMBackend,  # noqa: E402
                                Request, ServingEngine)


def main() -> None:
    cfg = reduced_config(get_config("qwen2.5-3b"))
    eng = ServingEngine(cfg, policy=Policy.EXEC_TIME, capacity=128)
    rng = np.random.default_rng(0)

    print("== normal traffic: policy decides placement per batch ==")
    for b in range(3):
        reqs = [Request(b * 4 + i, rng.integers(0, cfg.vocab_size, 12,
                                                dtype=np.int32), 6)
                for i in range(4)]
        comps = eng.serve_batch(reqs)
        print(f"batch {b}: prefill@{comps[0].prefill_venue:8s} "
              f"decode@{comps[0].decode_venue:8s} "
              f"latency={comps[0].latency_s:.3f}s")

    print("\n== burst: split prefill across 4 clones (paper §7.4) ==")
    reqs = [Request(100 + i, rng.integers(0, cfg.vocab_size, 12,
                                          dtype=np.int32), 4)
            for i in range(8)]
    comps = eng.serve_batch(reqs, n_clones=4, force="remote")
    print(f"burst: prefill@{comps[0].prefill_venue} "
          f"latency={comps[0].latency_s:.3f}s")

    print("\nstats:", eng.stats)
    print("pool:", eng.ec.pool.stats)

    print("\n== event-driven Client Handler: continuous batching under "
          "Poisson load (paper §5.2-5.3) ==")
    backend = LMBackend(cfg, capacity=64)
    handler = ClientHandler(backend, max_batch=4, max_secondaries=4,
                            prompt_pad=12)
    reqs = poisson_arrivals(8.0, 16, prompt_len=12, vocab=cfg.vocab_size,
                            max_new_tokens=6)
    report = handler.run(reqs, drain_idle_s=35.0)
    print(report.summary())
    print("pool:", report.pool_stats)
    print("secondaries now running:",
          len(handler.pool.running_secondaries()), "(paused after idle TTL)")


if __name__ == "__main__":
    main()
