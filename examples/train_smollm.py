"""End-to-end driver: train a ~100M-param SmolLM-style model for a few
hundred steps with checkpoint/restart and fault tolerance.

    PYTHONPATH=src python examples/train_smollm.py --steps 300
(defaults to a quick 60-step run; --full-width trains the ~100M config)
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config                      # noqa: E402
from repro.core import FaultPlan                          # noqa: E402
from repro.data.pipeline import DataConfig                # noqa: E402
from repro.launch.train import FleetTrainer               # noqa: E402
from repro.models import model                            # noqa: E402
from repro.optim.adamw import OptConfig                   # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-width", action="store_true",
                    help="~100M params (slow on CPU); default is a thin "
                         "8-layer variant of the same architecture")
    ap.add_argument("--ckpt-dir", default="/tmp/thinkair_smollm_ckpt")
    args = ap.parse_args()

    cfg = get_config("smollm-360m")
    if not args.full_width:
        cfg = dataclasses.replace(cfg, n_layers=8, d_model=192, n_heads=3,
                                  n_kv_heads=1, head_dim=64, d_ff=512,
                                  vocab_size=8192, dtype="float32")
    print(f"arch={cfg.name} params={model.n_params(cfg):,}")

    trainer = FleetTrainer(
        cfg, steps_total=args.steps,
        data_cfg=DataConfig(args.batch, args.seq),
        opt_cfg=OptConfig(peak_lr=1e-3, warmup_steps=20,
                          decay_steps=args.steps),
        ckpt_dir=args.ckpt_dir, ckpt_every=20,
        fault_plan=FaultPlan(fail_every=75),   # inject a failure mid-run
    )
    t0 = time.time()
    state = trainer.init_state()
    i = 0
    while i < args.steps:
        batch = trainer.pipe.batch(i)
        if trainer.faults.check():
            print(f"step {i}: INJECTED NODE FAILURE -> restart from ckpt")
            from repro.checkpoint import checkpoint as ckpt
            if ckpt.latest_step(args.ckpt_dir) is not None:
                i, state = ckpt.restore(args.ckpt_dir, state)
            trainer.report.restarts += 1
            continue
        state, m = trainer.step_fn(state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} ({time.time() - t0:.0f}s)")
        if i % 20 == 0 and i > 0:
            from repro.checkpoint import checkpoint as ckpt
            ckpt.save(args.ckpt_dir, i, state)
        i += 1
    print(f"done: {args.steps} steps, restarts={trainer.report.restarts}, "
          f"{time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
