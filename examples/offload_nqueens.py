"""N-queens with multi-clone parallelization (paper §7.4, Figure 12).

    PYTHONPATH=src python examples/offload_nqueens.py [--n 8]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workloads import nqueens_method          # noqa: E402
from repro.core import ExecutionController, Policy       # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8)
    args = ap.parse_args()

    rm = nqueens_method(args.n)
    space = args.n ** args.n
    ec = ExecutionController(policy=Policy.EXEC_TIME, link="wifi-local")
    ec.pool.provision("main", 10)      # paused secondaries, as in the paper

    local = ec.execute(rm, 0, space, force="local")
    print(f"phone:        {local.time_s:9.2f}s  {local.energy_j:8.2f}J  "
          f"solutions={int(local.value)}")
    for k in (1, 2, 4, 8):
        r = ec.execute(rm, 0, space, force="remote", n_clones=k)
        sols = int(r.value) if k == 1 else int(r.value)
        print(f"cloud k={k}:   {r.time_s:9.2f}s  {r.energy_j:8.2f}J  "
              f"solutions={sols}  overhead={r.overhead_s:.2f}s")
    print()
    print(f"speedup vs phone with 8 clones: "
          f"{local.time_s / r.time_s:,.0f}x")
    print("clone pool stats:", ec.pool.stats)


if __name__ == "__main__":
    main()
