"""Quickstart: mark a method @remote, let ThinkAir place it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402

from repro.core import (ExecutionController, Policy, remote,  # noqa: E402
                        set_default_controller)

# 1. Create a controller (the phone-side Execution Controller) and make it
#    ambient, like the paper's per-thread controller.
ec = ExecutionController(policy=Policy.EXEC_TIME_AND_ENERGY,
                         link="wifi-local")
set_default_controller(ec)


# 2. Annotate offloadable methods (the paper's @Remote + Remoteable class).
@remote(size=lambda n: n)
def heavy_compute(n):
    """Compute-bound: candidate for offloading."""
    x = jnp.eye(128) * 0.99

    def body(i, acc):
        return jnp.tanh(acc @ x)

    return jax.lax.fori_loop(0, n * 100, body, jnp.ones((128, 128))).sum()


@remote(size=lambda x: x.size)
def light_compute(x):
    """Trivial: offloading would only pay the network tax."""
    return (x + 1).sum()


def main() -> None:
    print("policy:", ec.policy.value, "| link:", ec.network.active)
    print()
    # first encounters: environment-only decision; later: history-driven
    for i in range(3):
        r = ec.execute(heavy_compute.remoteable, 50)
        print(f"heavy_compute run {i}: offloaded={r.offloaded:d} "
              f"venue={r.venue:8s} time={r.time_s:7.3f}s "
              f"energy={r.energy_j:6.2f}J")
    for i in range(3):
        r = ec.execute(light_compute.remoteable, jnp.ones((8, 8)))
        print(f"light_compute run {i}: offloaded={r.offloaded:d} "
              f"venue={r.venue:8s} time={r.time_s:7.3f}s "
              f"energy={r.energy_j:6.2f}J")
    print()
    print("decisions:", ec.decisions)
    print("clone pool:", ec.pool.stats)
    # switching to a bad link flips the decision (paper §4.3)
    ec.set_link("3g")
    r = ec.execute(light_compute.remoteable, jnp.ones((8, 8)))
    print(f"after 3G switch: light_compute offloaded={r.offloaded}")


if __name__ == "__main__":
    main()
