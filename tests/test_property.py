"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.clones import resume_time
from repro.core.policy import Policy, Prediction, should_offload
from repro.core.profilers import size_bucket
from repro.core.parallel import split_batch, split_range
from repro.distributed.compression import dequantize_int8, quantize_int8

TIMES = st.floats(min_value=1e-6, max_value=1e6, allow_nan=False)


@given(tl=TIMES, el=TIMES, tr=TIMES, er=TIMES)
def test_policy_offload_implies_improvement(tl, el, tr, er):
    """Under any single-objective policy, offloading implies that objective
    strictly improves (the paper's definition)."""
    local, remote = Prediction(tl, el), Prediction(tr, er)
    if should_offload(Policy.EXEC_TIME, local, remote):
        assert remote.time_s < local.time_s
    if should_offload(Policy.ENERGY, local, remote):
        assert remote.energy_j < local.energy_j
    if should_offload(Policy.EXEC_TIME_AND_ENERGY, local, remote):
        assert remote.time_s < local.time_s
        assert remote.energy_j < local.energy_j
    assert not should_offload(Policy.NONE, local, remote)


@given(st.integers(min_value=1, max_value=64))
def test_resume_time_monotone_in_contention(k):
    assert resume_time(k + 1) > resume_time(k)
    assert resume_time(1) == 0.300


@given(st.floats(min_value=1.0, max_value=1e12))
def test_size_bucket_monotone(n):
    assert size_bucket(2 * n) >= size_bucket(n)


@given(st.integers(min_value=1, max_value=257), st.integers(1, 8))
def test_split_batch_roundtrip(n, k):
    x = np.arange(n, dtype=np.int64)
    shards = split_batch((x,), k)
    merged = np.concatenate([s[0] for s in shards])
    np.testing.assert_array_equal(merged, x)


@given(st.integers(0, 100), st.integers(1, 1000), st.integers(1, 16))
def test_split_range_covers_exactly(lo, width, k):
    hi = lo + width
    parts = split_range(lo, hi, k)
    assert parts[0][0] == lo and parts[-1][1] == hi
    for (a, b), (c, d) in zip(parts, parts[1:]):
        assert b == c and a <= b and c <= d


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([2, 4, 8]),
       st.lists(st.integers(min_value=1, max_value=24), min_size=1,
                max_size=4))
def test_block_table_gather_matches_contiguous_cache(seed, bs, lens):
    """For any block size, context lengths, and (shuffled) physical block
    placement, attention through a block-table gather equals attention over
    the same KV stored contiguously — the invariant that makes paged decode
    token-identical to the contiguous cohort cache."""
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    b, hq, hkv, d = len(lens), 4, 2, 16
    max_blk = max(-(-ln // bs) for ln in lens)
    n_blocks = sum(-(-ln // bs) for ln in lens) + 1
    kp = rng.standard_normal((n_blocks, bs, hkv, d)).astype(np.float32)
    vp = rng.standard_normal((n_blocks, bs, hkv, d)).astype(np.float32)
    q = rng.standard_normal((b, hq, d)).astype(np.float32)
    perm = list(rng.permutation(np.arange(1, n_blocks)))   # scattered blocks
    tables = np.zeros((b, max_blk), np.int32)
    for i, ln in enumerate(lens):
        for j in range(-(-ln // bs)):
            tables[i, j] = perm.pop()
    got = np.asarray(ref.paged_attention_ref(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
        jnp.asarray(tables), jnp.asarray(np.asarray(lens, np.int32))))
    for i, ln in enumerate(lens):
        nb = -(-ln // bs)
        kc = kp[tables[i, :nb]].reshape(-1, hkv, d)[:ln]
        vc = vp[tables[i, :nb]].reshape(-1, hkv, d)[:ln]
        want = np.asarray(ref.flash_attention_ref(
            jnp.asarray(q[i:i + 1, :, None]),
            jnp.swapaxes(jnp.asarray(kc[None]), 1, 2),
            jnp.swapaxes(jnp.asarray(vc[None]), 1, 2),
            causal=False))[:, :, 0]
        np.testing.assert_allclose(got[i:i + 1], want, atol=2e-5, rtol=2e-5)


class _AllocStubBackend:
    """Minimal backend for host-side allocator properties (no device)."""

    capacity = 32

    def init_paged_pool(self, max_slots, num_blocks, block_size):
        return {}


def _alloc_invariants(kv):
    """The refcounted-allocator safety net (ADR-003): every physical
    block is in exactly one of {free, cached-free, referenced}; refcounts
    equal the number of block-table references; the trash block and the
    cached-free list stay clean."""
    n = kv.num_blocks
    refcalc = np.zeros(n, np.int64)
    for s in range(kv.max_slots):
        for j in range(int(kv.n_blocks_of[s])):
            refcalc[int(kv.tables[s, j])] += 1
    assert (refcalc == np.asarray(kv.ref, np.int64)).all(), \
        "refcounts must equal the number of tables referencing each block"
    free = set(kv._free_blocks)
    cached = set(kv._cached_free)
    refd = {b for b in range(1, n) if kv.ref[b] > 0}
    assert len(kv._free_blocks) == len(free), "double-free: dup free list"
    assert not free & cached and not free & refd and not cached & refd
    assert free | cached | refd == set(range(1, n)), "leaked block"
    assert 0 not in free and 0 not in cached and kv.ref[0] == 0
    assert all(b in kv._node for b in cached), "cached-free must be indexed"


@settings(deadline=None, max_examples=40)
@given(st.integers(0, 2 ** 31 - 1),
       st.lists(st.tuples(st.integers(0, 3), st.integers(0, 15)),
                min_size=1, max_size=40))
def test_refcounted_allocator_never_leaks_or_double_frees(seed, ops):
    """For any interleaving of admit / decode-grow / free / preempt (and
    the round-boundary pending clear), the refcounted prefix-cache
    allocator never leaks a block, never double-frees, and every shared
    block's refcount equals the number of tables referencing it — under
    heavy prefix overlap, CoW splits, LRU eviction, and exhaustion."""
    from repro.launch.serve import KVBlockPool, PoolExhausted
    kv = KVBlockPool(_AllocStubBackend(), max_slots=3, block_size=4,
                     num_blocks=12)
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 7, 32).astype(np.int32)   # common ancestor
    live = []
    for kind, x in ops:
        if kind == 0 and kv.free_slots:              # admit
            pl = 1 + x % 14
            prompt = base[:pl].copy()
            if x % 3 == 0:                           # diverge the tail
                prompt[-1] = 90 + x % 4
            if kv.can_admit(prompt, x % 8):
                slot, _, _, _ = kv.alloc_slot(prompt, x % 8,
                                              force_suffix=x % 5 == 0)
                kv.active[slot] = True
                live.append(slot)
        elif kind == 1 and live:                     # decode growth
            counts = np.zeros((kv.max_slots,), np.int32)
            for s in live:
                counts[s] = 1 + x % 4
            try:
                kv.grow_for_window(counts)
                kv.pos[live] = np.minimum(kv.pos[live] + counts[live],
                                          kv.capacity)
            except PoolExhausted:
                pass                                 # engine would preempt
        elif kind == 2 and live:                     # retire/preempt/cancel
            slot = live.pop(x % len(live))
            if x % 2:
                kv.free_slot(slot)
            else:
                kv.cancel_slot(slot)
        else:                                        # round boundary
            kv.clear_pending()
        _alloc_invariants(kv)
    for slot in list(live):
        kv.free_slot(slot)
    _alloc_invariants(kv)
    assert not np.asarray(kv.ref).any()              # all refs returned


class _DecodeLoopRig:
    """Shared tiny model + paged decode state for the decode_loop property.

    Built once (module scope) so hypothesis examples only re-run the cheap
    decode calls; all shapes are fixed across examples, so the jitted
    decode_step / decode_loop compile exactly once each.
    """

    SLOTS, BLOCK, CAP, T = 3, 4, 16, 4

    def __init__(self):
        import test_models as tm      # sibling module (pytest sys.path)
        from repro.configs import get_config, reduced_config
        from repro.models import model
        from repro.models.context import RunContext
        self.model = model
        self.cfg = reduced_config(get_config("smollm-360m"))
        self.ctx = RunContext()
        self.params = model.init(self.cfg, jnp.asarray([0, 5],
                                                       dtype=jnp.uint32))
        self.cache, self.tables, self.tok, self.pos = tm._paged_decode_state(
            self.cfg, self.ctx, self.params, prompt_lens=[3, 5, 2],
            block_size=self.BLOCK, capacity=self.CAP)
        self._stepwise = tm._stepwise_decode

    def run(self, budgets, warmup):
        """Advance each slot ``warmup`` extra tokens (randomizing cursors),
        then compare decode_loop vs stepwise over ``budgets``."""
        import jax
        cache = jax.tree.map(jnp.copy, self.cache)
        warm = np.asarray(warmup, np.int32)
        tok, pos = self.tok, self.pos
        if warm.max() > 0:
            out, cache = self._stepwise(self.cfg, self.ctx, self.params,
                                        cache, self.tables, tok, pos, warm,
                                        self.BLOCK, self.CAP,
                                        int(warm.max()))
            rows = np.arange(self.SLOTS)
            tok = np.where(warm > 0, out[rows, warm - 1],
                           tok[:, 0])[:, None].astype(np.int32)
            pos = np.minimum(pos + warm, self.CAP)
        budgets = np.asarray(budgets, np.int32)
        want, _ = self._stepwise(self.cfg, self.ctx, self.params,
                                 jax.tree.map(jnp.copy, cache), self.tables,
                                 tok, pos, budgets, self.BLOCK, self.CAP,
                                 self.T)
        got, _ = self.model.decode_loop(
            self.cfg, self.params, cache, jnp.asarray(tok),
            jnp.asarray(pos), jnp.asarray(budgets), self.ctx,
            block_tables=jnp.asarray(self.tables), block_size=self.BLOCK,
            num_steps=self.T, capacity=self.CAP)
        np.testing.assert_array_equal(np.asarray(got), want)


_RIG = []


@settings(deadline=None, max_examples=12)
@given(st.lists(st.integers(0, _DecodeLoopRig.T), min_size=3, max_size=3),
       st.lists(st.integers(0, 3), min_size=3, max_size=3))
def test_decode_loop_token_identical_to_stepwise(budgets, warmup):
    """For any slot occupancy (budget 0 = empty slot), cursor offsets, and
    mid-window completions (budget < T), one decode_loop dispatch emits
    exactly the tokens of T host-driven decode_step dispatches — the
    invariant that lets the serving layer fuse T tokens per round-trip."""
    if not _RIG:
        _RIG.append(_DecodeLoopRig())
    _RIG[0].run(budgets, warmup)


@settings(deadline=None)
@given(st.lists(st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, width=32),
                min_size=1, max_size=64))
def test_int8_quantization_error_bound(xs):
    """|x - deq(quant(x))| <= scale/2 + eps elementwise."""
    g = jnp.asarray(xs, jnp.float32)
    q, scale = quantize_int8(g)
    err = np.abs(np.asarray(g) - np.asarray(dequantize_int8(q, scale)))
    assert err.max() <= float(scale) / 2 + 1e-5 + float(scale) * 1e-3


@given(st.integers(2, 64), st.integers(1, 63))
def test_spec_divisibility_fallback(dim_mult, off):
    """spec_for never assigns a mesh axis that does not divide the dim."""
    import jax
    from repro.distributed.sharding import spec_for
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # model axis size 1 divides everything -> sharding allowed
    spec = spec_for((dim_mult,), ("mlp",), mesh)
    assert spec == jax.sharding.PartitionSpec("model") or \
        spec == jax.sharding.PartitionSpec()


@settings(deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 3))
def test_energy_additivity(util10, bright16, secs):
    """PowerTutor components are independent: total = sum of parts."""
    from repro.core.energy import PhoneState, PowerTutorModel
    m = PowerTutorModel()
    st_ = PhoneState(cpu_util=util10 * 10.0, brightness=bright16 * 16)
    total = sum(m.energy_j(st_, float(secs)).values())
    parts = m.power_mw(st_)
    assert total == sum(v * 1e-3 * secs for v in parts.values())


# ---------------------------------------------------------------------------
# chunked paged prefill (ADR-005)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 996),
       prefix_lens=st.lists(st.integers(0, 8), min_size=3, max_size=3),
       n_tok=st.lists(st.integers(0, 8), min_size=3, max_size=3),
       chunk=st.sampled_from([1, 2, 3, 4, 8]))
def test_chunked_prefill_token_identical_to_stepwise(seed, prefix_lens,
                                                     n_tok, chunk):
    """ADR-005 property: for any per-row prefix/suffix lengths and any
    chunk size, the chunked suffix scan returns the stepwise scan's first
    tokens and leaves every live pool block bitwise identical.  (The
    deterministic twin lives in test_models.py so the invariant is still
    exercised where hypothesis is not installed.)"""
    import test_models as tm
    tm._check_chunked_vs_stepwise(prefix_lens, n_tok, chunk, seed=seed)


# ---------------------------------------------------------------------------
# fault-injected serving (ADR-006)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 7),
       hedge=st.sampled_from([0.0, 2.0]),
       faults=st.lists(
           st.tuples(st.floats(min_value=0.05, max_value=0.95),
                     st.sampled_from(["kill", "drain", "slow"]),
                     st.sampled_from([0.0, 0.5, 2.0]),
                     st.sampled_from([4.0, 40.0])),
           min_size=1, max_size=3))
def test_fault_recovery_conserves_requests_and_blocks(seed, hedge, faults):
    """ADR-006 property: for any schedule of kill/drain/slow faults (any
    times, durations, slowdown factors, hedging on or off), the handler
    loses no request, leaks no KV block, emits tokens bit-identical to
    the faultless run, and keeps its recovery counters consistent.
    (``run_chaos_trace`` asserts block conservation internally; its
    deterministic twin lives in test_faults.py so the invariant is still
    exercised where hypothesis is not installed.)"""
    import test_faults as tf
    from repro.core.faults import CloneFault
    base = tf.run_chaos_trace(seed=seed)
    span = base["makespan_s"]
    sched = [CloneFault(at=frac * span, kind=kind, duration=dur,
                        factor=factor)
             for frac, kind, dur, factor in faults]
    out = tf.run_chaos_trace(sched, hedge=hedge, seed=seed)
    assert out["served"] == out["offered"] == base["served"]   # none lost
    assert out["tokens"] == base["tokens"]     # recovery is latency-only
    assert out["injected"] <= len(sched)
    assert out["hedge_wins"] <= out["hedges_fired"]
    if hedge == 0.0:
        assert out["hedges_fired"] == 0
    if not any(k == "drain" for _, k, _, _ in faults):
        assert out["migrated"] == 0            # only drains salvage KV


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2 ** 31 - 1),
       adv_weight=st.floats(min_value=0.25, max_value=8.0,
                            allow_nan=False),
       adv_cost=st.sampled_from([1, 2, 4, 8]),
       adv_n=st.integers(10, 80),
       rate=st.sampled_from([2.0, 4.0, 8.0, 16.0]),
       burst=st.sampled_from([2.0, 8.0, 16.0]))
def test_gateway_quota_and_fair_share(seed, adv_weight, adv_cost, adv_n,
                                      rate, burst):
    """ADR-007 property: under any adversarial arrival mix — a flooding
    tenant of arbitrary weight/cost/volume against a steady victim and a
    token-bucket-metered tenant — the gateway's release schedule never
    lets the metered tenant exceed ``burst + rate x t`` cumulative
    tokens, and the victim's weight-normalized share stays within a
    DRR-granularity bound (it is never starved).  The deterministic twin
    lives in test_gateway.py (``check_quota_invariants``)."""
    import test_gateway as tg
    tg.check_quota_invariants(tg.run_quota_trace(
        adv_weight=adv_weight, adv_cost=adv_cost, adv_n=adv_n,
        rate=rate, burst=burst, seed=seed))


# ---------------------------------------------------------------------------
# cross-tier speculative decoding (ADR-008)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 996),
       prompt_lens=st.lists(st.integers(1, 8), min_size=2, max_size=3),
       budgets=st.lists(st.integers(0, 7), min_size=3, max_size=3),
       k_max=st.integers(1, 4),
       flip_p=st.sampled_from([0.0, 0.3, 0.6, 1.0]))
def test_speculative_decode_token_identical_to_stepwise(seed, prompt_lens,
                                                        budgets, k_max,
                                                        flip_p):
    """ADR-008 property: for any draft-agreement pattern — random per-row
    per-round window sizes K, mid-window rejections (proposals corrupted
    with probability ``flip_p``), dead rows (budget 0), ragged budgets —
    the draft_loop + verify_window rounds emit a stream bitwise identical
    to stepwise greedy decode, and the committed KV they leave behind is
    indistinguishable under continuation.  (The deterministic twin lives
    in test_models.py so the invariant is still exercised where
    hypothesis is not installed.)"""
    import test_models as tm
    tm._check_spec_vs_stepwise(prompt_lens + [1] * (3 - len(prompt_lens)),
                               budgets, k_max, flip_p, seed=seed)


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 2 ** 31 - 1), chunk=st.sampled_from([2, 4, 8]))
def test_chunked_serving_preemption_invariant(seed, chunk):
    """ADR-005 property: serving a seeded shared-prefix trace on a tight
    pool — mid-stream preemptions, restores, prefix hits — is observably
    invariant to prefill chunking: identical per-request tokens and
    identical KVBlockPool refcount economics (preemption / restored /
    prefix-hit counters)."""
    import test_handler as th
    assert th._run_tight_chunk_trace(seed, 0, False) == \
        th._run_tight_chunk_trace(seed, chunk, True)


# ---------------------------------------------------------------------------
# disaggregated prefill/decode + affinity routing (ADR-009)
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 2 ** 31 - 1),
       routing=st.sampled_from(["ledger", "affinity", "random"]),
       compress=st.booleans())
def test_disagg_affinity_conserves_blocks_and_tokens(seed, routing,
                                                     compress):
    """ADR-009 property: for any seeded shared-prefix trace, routing
    mode, and compression setting, disaggregated serving (partner
    prefill + cross-clone paged-KV migration) loses no request, leaks
    and double-frees no block in any per-clone or partner scratch pool
    (asserted inside the helper), always hands off at least one cold
    prompt, and — compression off — emits streams bitwise identical to
    the co-located ledger-routed greedy baseline.  (The deterministic
    twin lives in test_handler.py so the invariant is still exercised
    where hypothesis is not installed.)"""
    import test_handler as th
    base = th.run_disagg_affinity_trace(seed)
    out = th.run_disagg_affinity_trace(seed, routing=routing,
                                       disagg=True, compress=compress)
    assert out["served"] == out["offered"] == base["served"]
    assert out["handoffs"] >= 1
    assert out["xfer_bytes"] > 0
    if not compress:
        assert out["tokens"] == base["tokens"]
