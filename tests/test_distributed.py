"""Distribution correctness: sharded == unsharded numerics."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention_xla

KEY = jax.random.PRNGKey(11)


def test_seq_sharded_attention_core_matches_default():
    """The shard-aware (B, M, rows) attention regrouping is numerically
    identical to the flat path (machinery check on one device)."""
    b, s, hq, hkv, d = 2, 256, 6, 2, 32
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, s, hq, d))
    k = jax.random.normal(k2, (b, s, hkv, d))
    v = jax.random.normal(k3, (b, s, hkv, d))
    base = attention_xla(q, k, v, causal=True, q_chunk=64)
    for shards in (2, 4, 8):
        out = attention_xla(q, k, v, causal=True, q_chunk=64,
                            seq_shards=shards)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"seq_shards={shards}")


_SUBPROCESS_SRC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "{src}")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduced_config
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.launch import steps as S
    from repro.models import model
    from repro.optim import adamw
    from repro.optim.adamw import OptConfig

    cfg = reduced_config(get_config("{arch}"))
    pipe = Pipeline(cfg, DataConfig(8, 16, seed=3))
    batch = pipe.batch(0)
    params = model.init(cfg, jax.random.PRNGKey(0))
    state = {{"params": params, "opt": adamw.init(params)}}
    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)

    # drop-free MoE capacity: per-shard capacity semantics otherwise differ
    # (legitimately) between the local and EP paths
    capf = cfg.n_experts / cfg.top_k if cfg.is_moe else 1.25
    # single-device reference
    ctx0 = S.make_context(None, moe_capacity_factor=capf)
    step0 = jax.jit(S.build_train_step(cfg, opt_cfg, ctx0))
    s0, m0 = step0(state, batch)
    # 2x4 production-axis mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx1 = S.make_context(mesh, moe_capacity_factor=capf)
    sh = S.state_shardings(cfg, mesh)
    from repro.distributed import sharding as shd
    bsh = shd.input_shardings(batch, mesh, 8)
    step1 = jax.jit(S.build_train_step(cfg, opt_cfg, ctx1),
                    in_shardings=(sh, bsh))
    s1, m1 = step1(state, batch)
    l0, l1 = float(m0["loss"]), float(m1["loss"])
    g0, g1 = float(m0["grad_norm"]), float(m1["grad_norm"])
    assert abs(l0 - l1) < 5e-3, (l0, l1)
    assert abs(g0 - g1) / max(g0, 1e-6) < 2e-2, (g0, g1)
    print(f"OK loss {{l0:.5f}}=={{l1:.5f}} gnorm {{g0:.4f}}=={{g1:.4f}}")
""")


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen2.5-3b",
                                  "olmoe-1b-7b", "rwkv6-7b"])
def test_sharded_train_step_matches_single_device(arch):
    """Full train step on a 2x4 (data, model) mesh reproduces the
    single-device loss/grad-norm — validates the entire sharding stack
    (FSDP+TP rules, shard_map MoE, vocab-sharded CE, constraints)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _SUBPROCESS_SRC.format(src=os.path.abspath(src), arch=arch)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout
