"""SLO-aware streaming gateway (ADR-007): token-bucket quotas, DRR fair
share, predictive admission, batch-only shedding, deterministic
Retry-After backpressure, response cache, and the breaker-cap plumbing.
Everything runs on the VirtualClock — no real sleeps."""
import math

import numpy as np
import pytest

from repro.core import SystemClock, VirtualClock
from repro.core.clones import CircuitBreaker, ClonePool, CloneState
from repro.core.gateway import (AdmissionEstimator, ResponseCache,
                                StreamingGateway, TenantPolicy, TokenBucket)
from repro.core.scheduler import ServeCompletion, ServeRequest


# --------------------------------------------------------------------------- #
# token bucket + policy units
# --------------------------------------------------------------------------- #
def test_token_bucket_validates_rate_and_policy_weight():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=-1.0)
    with pytest.raises(ValueError):
        TenantPolicy(weight=0.0)
    assert math.isinf(TokenBucket().burst)      # unmetered default


def test_token_bucket_starts_full_then_refills_continuously():
    b = TokenBucket(rate=4.0, burst=8.0)
    assert b.take(0.0, 8.0)                     # full burst available
    assert not b.take(0.0, 1.0)                 # drained
    assert b.eta(0.0, 2.0) == pytest.approx(0.5)    # 2 tokens at 4/s
    assert not b.take(0.25, 2.0)                # only 1 refilled so far
    assert b.take(0.5, 2.0)
    # refill never exceeds burst
    assert b.take(100.0, 8.0) and not b.take(100.0, 1e-9 + 1.0)


def test_response_cache_exact_match_lru():
    cache = ResponseCache(max_entries=2)
    reqs = [ServeRequest(i, np.full(4, i, np.int32), max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        assert cache.get(r) is None
        cache.put(r, [1, 2, 3, 4])
    assert len(cache) == 2
    assert cache.get(reqs[0]) is None           # LRU-evicted
    assert cache.get(reqs[2]) == [1, 2, 3, 4]
    # same prompt, different token budget -> different key
    other = ServeRequest(9, np.full(4, 2, np.int32), max_new_tokens=8)
    assert cache.get(other) is None
    assert cache.hits == 1 and cache.misses == 5


def test_estimator_ema_and_fault_inflation():
    est = AdmissionEstimator(tpot0=0.1, alpha=0.5)
    est.observe(0.3)
    assert est.tpot_s == pytest.approx(0.2)
    # half the fleet dead -> double the expected queueing delay
    assert est.wait_s(10.0, 2, 0.5) == pytest.approx(
        2 * est.wait_s(10.0, 2, 1.0))


def test_gateway_clock_binding():
    gw = StreamingGateway()
    with pytest.raises(TypeError):
        gw.adopt_clock(SystemClock())           # wall clock: not virtual
    clk = VirtualClock()
    gw.adopt_clock(clk)
    gw.adopt_clock(clk)                         # idempotent
    with pytest.raises(ValueError):
        gw.adopt_clock(VirtualClock())


# --------------------------------------------------------------------------- #
# release: DRR fair share, class priority, quota backpressure
# --------------------------------------------------------------------------- #
class _Sink:
    """Stand-in for the handler's AdmissionQueue: records releases."""

    def __init__(self):
        self.released = []                      # (t, tenant, rid, cost)

    def offer(self, req, now):
        self.released.append((now, req.tenant, req.rid,
                              max(1, req.max_new_tokens)))
        return True


def _req(rid, tenant, *, cost=1, arrival=0.0, slo="batch", deadline=None,
         priority=0, prompt=None):
    p = prompt if prompt is not None else np.zeros(4, np.int32)
    return ServeRequest(rid, p, max_new_tokens=cost, arrival_t=arrival,
                        priority=priority, tenant=tenant, slo=slo,
                        deadline_s=deadline)


def test_drr_release_is_weighted_fair():
    clk = VirtualClock()
    gw = StreamingGateway(clock=clk, quantum=1.0, tenants={
        "heavy": TenantPolicy(weight=3.0), "light": TenantPolicy()})
    for i in range(10):
        gw.offer(_req(i, "heavy"), 0.0)
        gw.offer(_req(100 + i, "light"), 0.0)
    sink = _Sink()
    assert gw.release(0.0, sink, budget=8) == 8
    by = {"heavy": 0, "light": 0}
    for _, tenant, _, _ in sink.released:
        by[tenant] += 1
    assert by == {"heavy": 6, "light": 2}       # 3:1 deficit split


def test_interactive_releases_before_any_batch():
    clk = VirtualClock()
    gw = StreamingGateway(clock=clk)
    gw.offer(_req(0, "a", arrival=0.0), 0.0)            # batch, earliest
    gw.offer(_req(1, "b", arrival=0.5, slo="interactive", deadline=9.0),
             0.5)
    sink = _Sink()
    gw.release(0.5, sink, budget=2)
    assert [r[2] for r in sink.released] == [1, 0]      # class before FIFO


def test_quota_blocked_head_surfaces_bucket_eta():
    clk = VirtualClock()
    gw = StreamingGateway(clock=clk, quantum=8.0, tenants={
        "metered": TenantPolicy(rate=4.0, burst=4.0)})
    gw.offer(_req(0, "metered", cost=4), 0.0)
    gw.offer(_req(1, "metered", cost=4), 0.0)
    sink = _Sink()
    assert gw.release(0.0, sink, budget=4) == 1         # bucket drained
    assert gw.next_event_time() == pytest.approx(1.0)   # 4 tokens at 4/s
    clk.advance_to(1.0)
    assert gw.release(1.0, sink, budget=4) == 1
    assert gw.queued == 0


# --------------------------------------------------------------------------- #
# admission: predictive rejection, shedding, backpressure
# --------------------------------------------------------------------------- #
def test_predictive_rejection_is_link_honest():
    """The same deadline request is admitted on wifi-local but rejected
    up front on 3g: the admission estimate prices the link transfer."""
    big = np.zeros(25_000, np.int32)            # 100 KB prompt
    for link, want in (("wifi-local", "queued"), ("3g", "rejected")):
        gw = StreamingGateway(clock=VirtualClock(), link=link, tpot0=1e-3)
        r = _req(0, "t", cost=1, slo="interactive", deadline=0.3,
                 prompt=big)
        assert gw.offer(r, 0.0) == want, link
    assert gw.rejected_by_slo == {"interactive": 1}


def test_shedding_never_victimizes_interactive():
    clk = VirtualClock()
    gw = StreamingGateway(clock=clk, max_backlog_tokens=8.0,
                          retry_max=0)
    assert gw.offer(_req(0, "t", cost=4, priority=1), 0.0) == "queued"
    assert gw.offer(_req(1, "t", cost=4, priority=0), 0.1) == "queued"
    # over the bound: the lowest-priority batch request is the victim
    assert gw.offer(_req(2, "t", cost=4, priority=2), 0.2) == "queued"
    assert gw.shed == 1                          # rid 1 (priority 0) shed
    # interactive overflow sheds batch work, never itself
    assert gw.offer(_req(3, "t", cost=4, slo="interactive"), 0.3) \
        == "queued"
    assert gw.shed == 2 and gw.shed_by_slo == {"batch": 2}
    assert sorted(r.rid for q in gw._queues.values() for r in q) == [2, 3]


def test_retry_after_is_deterministic_and_bounded():
    def run():
        clk = VirtualClock()
        gw = StreamingGateway(clock=clk, max_backlog_tokens=1.0,
                              retry_base_s=0.25, retry_max=2, seed=7)
        gw.offer(_req(0, "t", cost=4), 0.0)      # over bound: shed+retry
        while gw.pending:
            nxt = gw.next_event_time()
            assert nxt is not None and nxt > clk.now()
            clk.advance_to(nxt)
        return list(gw.retry_log), gw.shed, gw.dropped
    log1, shed1, dropped1 = run()
    log2, shed2, dropped2 = run()
    assert log1 == log2                          # replayable backpressure
    assert [a for _, a, _ in log1] == [1, 2]     # capped at retry_max
    assert shed1 == shed2 == 3 and dropped1 == dropped2 == 1
    # exponential spacing: attempt 2 waits longer than attempt 1
    assert log1[1][2] - log1[0][2] > log1[0][2]


def test_deadline_work_is_never_retried():
    clk = VirtualClock()
    gw = StreamingGateway(clock=clk, max_backlog_tokens=1.0, tpot0=1e-6)
    gw.offer(_req(0, "t", cost=4, deadline=50.0), 0.0)
    assert gw.shed == 1 and gw.retries == 0 and gw.pending == 0


def test_completion_feedback_populates_cache():
    clk = VirtualClock()
    gw = StreamingGateway(clock=clk)
    prompt = np.arange(6, dtype=np.int32)
    gw.offer(_req(0, "t", cost=4, prompt=prompt), 0.0)
    sink = _Sink()
    gw.release(0.0, sink, budget=1)
    gw.observe_completion(ServeCompletion(
        0, [5, 6, 7, 8], 0.0, 0.2, 0.5, "venue:0",
        token_ts=[0.2, 0.3, 0.4, 0.5]))
    assert gw.estimator.samples == 1
    # an exact repeat is served at the door
    assert gw.offer(_req(1, "t", cost=4, prompt=prompt), 1.0) == "cached"
    out = gw.drain_cached()
    assert len(out) == 1 and out[0].cached
    assert out[0].venue == "gateway-cache" and out[0].tokens == [5, 6, 7, 8]
    assert out[0].met_deadline


# --------------------------------------------------------------------------- #
# deterministic quota/fairness twin (hypothesis property delegates here)
# --------------------------------------------------------------------------- #
def run_quota_trace(*, adv_weight=1.0, adv_cost=2, adv_n=60, victim_n=16,
                    rate=8.0, burst=8.0, metered_n=30, horizon=8.0,
                    dt=0.25, budget=4, quantum=2.0, seed=0):
    """Drive a gateway release loop over an adversarial arrival mix.

    Three tenants: a ``victim`` (weight 1, cost-1 requests), a flooding
    ``adversary`` (arbitrary weight/cost), and a ``metered`` tenant whose
    token bucket is the quota under test.  Returns the release record for
    the invariant checks in :func:`check_quota_invariants` — the
    deterministic twin of the hypothesis property in test_property.py."""
    rng = np.random.default_rng(seed)
    clk = VirtualClock()
    gw = StreamingGateway(clock=clk, quantum=quantum, seed=seed, tenants={
        "victim": TenantPolicy(weight=1.0),
        "adversary": TenantPolicy(weight=adv_weight),
        "metered": TenantPolicy(weight=1.0, rate=rate, burst=burst),
    })
    arrivals = (
        [_req(i, "victim", cost=1, arrival=0.0) for i in range(victim_n)]
        + [_req(1000 + i, "adversary", cost=adv_cost,
                arrival=0.0 if i < adv_n // 2 else horizon / 2)
           for i in range(adv_n)]
        + [_req(2000 + i, "metered", cost=2,
                arrival=float(rng.uniform(0, horizon / 2)))
           for i in range(metered_n)])
    arrivals.sort(key=lambda r: (r.arrival_t, r.rid))
    sink = _Sink()
    i, t = 0, 0.0
    while t <= horizon + 1e-9:
        if t > clk.now():
            clk.advance_to(t)
        while i < len(arrivals) and arrivals[i].arrival_t <= t + 1e-9:
            gw.offer(arrivals[i], t)
            i += 1
        gw.release(t, sink, budget)
        t += dt
    return {"released": sink.released, "rate": rate, "burst": burst,
            "adv_weight": adv_weight, "quantum": quantum,
            "victim_n": victim_n, "max_cost": max(adv_cost, 2)}


def check_quota_invariants(out):
    """The two ADR-007 safety properties, checked on a release record."""
    rate, burst = out["rate"], out["burst"]
    # 1. quota: the metered tenant never exceeds bucket rate — at every
    #    release instant its cumulative tokens fit burst + rate * t
    tok = 0.0
    for t, tenant, _, cost in out["released"]:
        if tenant == "metered":
            tok += cost
            assert tok <= burst + rate * t + 1e-6, (t, tok)
    # 2. fairness: while the victim is backlogged, its weight-normalized
    #    service stays within a DRR-granularity bound of the adversary's
    v_tok = a_tok = v_seen = 0.0
    slack = 2 * out["quantum"] * max(1.0, out["adv_weight"]) \
        + 2 * out["max_cost"]
    for _, tenant, _, cost in out["released"]:
        if tenant == "victim":
            v_tok += cost
            v_seen += 1
        elif tenant == "adversary":
            a_tok += cost
        if v_seen < out["victim_n"]:            # victim still backlogged
            assert a_tok / out["adv_weight"] - v_tok <= slack, \
                (v_tok, a_tok)
    assert v_seen == out["victim_n"]            # and never starved out


def test_quota_trace_deterministic_twin():
    for kw in ({}, {"adv_weight": 6.0, "adv_cost": 4},
               {"rate": 2.0, "burst": 2.0, "adv_weight": 0.5}):
        check_quota_invariants(run_quota_trace(**kw))
    # identical seeds replay identical release timelines
    assert run_quota_trace(seed=3) == run_quota_trace(seed=3)


# --------------------------------------------------------------------------- #
# breaker caps (ADR-006 constants -> ADR-007 constructor parameters)
# --------------------------------------------------------------------------- #
def test_breaker_custom_caps_bound_probe_chain():
    clk = VirtualClock()
    b = CircuitBreaker(open_seconds=0.5, max_open_seconds=1.0,
                       max_probes=3)
    b.bind(clk, lambda: False)
    b.trip(0.0)
    # probes at 0.5, then cooldown doubles but caps at 1.0: 1.5, 2.5
    clk.advance_to(2.6)
    assert b.probes == 3 and b.state == "open"  # chain exhausted
    clk.advance_to(100.0)
    assert b.probes == 3                        # max_probes respected
    # with the default 30 s cap the third probe lands at 3.5, not 2.5:
    # the custom cap measurably shortens the backoff chain
    clk2 = VirtualClock()
    d = CircuitBreaker(open_seconds=0.5)
    d.bind(clk2, lambda: False)
    d.trip(0.0)
    clk2.advance_to(2.6)
    assert d.probes == 2


def test_handler_surfaces_breaker_caps():
    import test_handler as th
    h = th._make_handler(max_secondaries=2, breaker_max_open_s=3.0,
                         breaker_max_probes=2)
    assert h.pool.clones
    for c in h.pool.clones:
        assert c.breaker.max_open_seconds == 3.0
        assert c.breaker.max_probes == 2
    # a supplied pool gets its existing clones retrofitted too
    clk = VirtualClock()
    pool = ClonePool(clock=clk)
    pool.provision("main", 2, state=CloneState.RUNNING)
    from repro.launch.serve import ClientHandler
    ClientHandler(th.FakeBackend(),
                  executor=lambda c, f, a: (f(*a), 0.05),
                  pool=pool, clock=clk, breaker_max_open_s=2.5)
    for c in pool.clones:
        assert c.breaker.max_open_seconds == 2.5


# --------------------------------------------------------------------------- #
# end-to-end through the Client Handler
# --------------------------------------------------------------------------- #
def _trace(n, *, rate=20.0, cost=4, seed=0, dup_every=0, deadline=None):
    rng = np.random.default_rng(seed)
    dup = rng.integers(0, 50, 6).astype(np.int32)
    reqs = []
    for i in range(n):
        prompt = dup if dup_every and i % dup_every == 2 \
            else rng.integers(0, 50, 6).astype(np.int32)
        reqs.append(ServeRequest(
            i, prompt, max_new_tokens=cost, arrival_t=i / rate,
            tenant=("premium" if i % 3 == 0 else "bulk"),
            slo=("interactive" if i % 3 == 0 else "batch"),
            deadline_s=deadline if i % 3 == 0 else None))
    return reqs


def _gated_handler(gw, **kw):
    import test_handler as th
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_secondaries", 1)
    return th._make_handler(gateway=gw, **kw)


def test_gated_run_serves_everything_at_low_load():
    import test_handler as th
    base = th._make_handler(max_batch=2, max_secondaries=1)
    rep0 = base.run(_trace(8), drain_idle_s=40.0)
    gw = StreamingGateway(tenants={"premium": TenantPolicy(weight=4.0)})
    rep1 = _gated_handler(gw).run(_trace(8), drain_idle_s=40.0)
    toks = {c.rid: c.tokens for c in rep0.completions}
    assert {c.rid: c.tokens for c in rep1.completions} == toks
    assert gw.shed == 0 and gw.rejected == 0
    assert rep1.slo_attainment.get("batch") == 1.0
    # per-tenant streaming stats populated from token_ts
    assert set(rep1.per_tenant) == {"premium", "bulk"}
    for row in rep1.per_tenant.values():
        assert row["served"] > 0 and row["p50_tpot_s"] >= 0.0


def test_gateway_cache_end_to_end():
    gw = StreamingGateway()
    # arrivals spaced wider than a request's service time, so a repeat
    # lands after its twin's completion has populated the cache
    rep = _gated_handler(gw).run(_trace(10, rate=3.0, dup_every=3),
                                 drain_idle_s=40.0)
    assert rep.cache_hits >= 1
    cached = [c for c in rep.completions if c.cached]
    assert cached and all(c.venue == "gateway-cache" for c in cached)
    by_rid = {c.rid: c for c in rep.completions}
    for c in cached:                             # identical to the miss
        first = min(r for r, cc in by_rid.items()
                    if cc.tokens == c.tokens and not cc.cached)
        assert by_rid[first].tokens == c.tokens
    assert len(rep.completions) == 10            # cache loses nothing


def test_retry_replay_is_deterministic_end_to_end():
    """Satellite 6: same seed -> identical Retry-After timeline and
    identical final ServeReport under shed-heavy overload."""
    def run():
        gw = StreamingGateway(max_backlog_tokens=8.0, quantum=4.0,
                              retry_base_s=0.3, retry_max=2, seed=11)
        rep = _gated_handler(gw, queue_depth=4).run(
            _trace(16, rate=200.0), drain_idle_s=40.0)
        return gw, rep
    gw1, rep1 = run()
    gw2, rep2 = run()
    assert gw1.retry_log and gw1.retry_log == gw2.retry_log
    for field in ("gateway_shed", "gateway_retries", "gateway_rejected",
                  "slo_attainment", "goodput_tps", "peak_queue_depth",
                  "makespan_s"):
        assert getattr(rep1, field) == getattr(rep2, field), field
    assert sorted(c.rid for c in rep1.completions) == \
        sorted(c.rid for c in rep2.completions)


def test_fault_signal_tightens_admission():
    gw = StreamingGateway(clock=VirtualClock(), max_backlog_tokens=100.0)
    gw.observe_fleet(4, 4, 8)
    assert gw.healthy_frac() == 1.0
    gw.note_fault()
    gw.note_fault()
    assert gw.healthy_frac() == pytest.approx(0.5)
    assert gw.fault_signals == 2
    gw.observe_fleet(2, 4, 4)                    # census supersedes
    assert gw.healthy_frac() == pytest.approx(0.5)
