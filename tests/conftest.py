import os
import sys

# src-layout import path (so `pytest tests/` works without install)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
