"""Fault-injected serving: chaos harness, breaker, hedging (ADR-006).

Layered like the machinery it tests:

- :class:`CircuitBreaker` state machine units (closed -> open ->
  half-open -> closed, cooldown doubling, the clock-driven probe chain);
- :class:`ReconnectManager` backoff as VirtualClock events (plus the
  seed's synchronous mode, which must stay untouched);
- :class:`FaultInjector` units: kill/drain/slow firing, targeting,
  misses, revival, recovery bookkeeping;
- end-to-end chaos on the :class:`~repro.launch.serve.ClientHandler`:
  kill a clone mid-decode and assert the served tokens are
  **bit-identical** to the faultless run for BOTH recovery paths —
  drain -> KV migration to a survivor, kill -> prefix-accelerated
  restore — on the FakeBackend and on a real reduced LM backend;
- hedged dispatch: a straggling clone's decode window races a duplicate
  on a warm spare, the winner's tokens are used, the loser is cancelled,
  and nothing is double-billed.

``run_chaos_trace`` at the bottom is the deterministic twin the
Hypothesis property test (test_property.py) drives with random fault
schedules; the leak/conservation checks live here so both suites assert
the same invariants.
"""
import numpy as np
import pytest

from repro.core.clock import VirtualClock
from repro.core.clones import (CB_FAIL_THRESHOLD, CircuitBreaker, Clone,
                               CloneHealth, ClonePool, CloneState)
from repro.core.dispatch import Dispatcher
from repro.core.faults import CloneFault, FaultInjector, ReconnectManager
from repro.core.scheduler import ServeRequest, poisson_arrivals

import test_handler as th


# --------------------------------------------------------------- breaker
def test_breaker_threshold_opens_and_allow_gates():
    cb = CircuitBreaker()
    assert cb.state == "closed"
    for _ in range(CB_FAIL_THRESHOLD - 1):
        cb.record_failure(now=0.0)
    assert cb.state == "closed"
    cb.record_failure(now=0.0)
    assert cb.state == "open" and cb.opens == 1
    # open gate: refuse inside the cooldown, half-open after it
    assert not cb.allow(now=0.5)
    assert cb.state == "open"
    assert cb.allow(now=1.5)             # past open_seconds=1.0
    assert cb.state == "half_open"
    cb.record_success()
    assert cb.state == "closed" and cb.failures == 0


def test_breaker_halfopen_failure_reopens_with_doubled_cooldown():
    cb = CircuitBreaker()
    cb.trip(now=0.0)
    assert cb.allow(now=1.5) and cb.state == "half_open"
    cb.record_failure(now=1.5)           # probe failed: reopen
    assert cb.state == "open" and cb.opens == 2
    # cooldown doubled: 2.0s now
    assert not cb.allow(now=2.5)
    assert cb.allow(now=3.6)
    cb.record_success()
    assert cb.state == "closed"
    # success resets the cooldown back to base
    cb.trip(now=10.0)
    assert not cb.allow(now=10.9)
    assert cb.allow(now=11.1)


def test_breaker_clock_probe_chain_closes_on_success():
    clock = VirtualClock()
    healthy = {"v": False}
    cb = CircuitBreaker()
    cb.bind(clock, lambda: healthy["v"])
    cb.trip(clock.now())
    assert cb.state == "open"
    clock.advance(1.1)                   # first probe: target still down
    assert cb.state == "open" and cb.opens == 2
    healthy["v"] = True
    clock.advance(2.1)                   # doubled cooldown, second probe
    assert cb.state == "closed"
    assert cb.probes == 2


def test_breaker_probe_budget_exhausts():
    clock = VirtualClock()
    cb = CircuitBreaker(max_probes=3)
    cb.bind(clock, lambda: False)
    cb.trip(clock.now())
    clock.advance(1000.0)                # far past every backoff stage
    assert cb.probes == 3                # budget spent, chain stopped
    assert cb.state == "open"


def test_breaker_success_cancels_pending_probe():
    clock = VirtualClock()
    calls = []
    cb = CircuitBreaker()
    cb.bind(clock, lambda: calls.append(1) or True)
    cb.trip(clock.now())
    cb.record_success()                  # external recovery before probe
    clock.advance(50.0)
    assert calls == [] and cb.state == "closed"


# ----------------------------------------------------------- reconnect
def test_reconnect_clock_mode_backoff_timing():
    clock = VirtualClock()
    times = []

    def attempt():
        times.append(clock.now())
        return len(times) >= 4           # succeed on the 4th try

    rm = ReconnectManager(attempt, base_delay=0.1, max_delay=0.5,
                          max_attempts=8, clock=clock)
    rm.notify_failure()
    assert not rm.connected and times == []     # nothing runs inline
    clock.advance(10.0)
    # 0.1, then doubling 0.2, 0.4, capped 0.5 between attempts
    np.testing.assert_allclose(times, [0.1, 0.3, 0.7, 1.2])
    assert rm.connected and rm.attempts == 4


def test_reconnect_clock_mode_burst_cap_and_rearm():
    clock = VirtualClock()
    rm = ReconnectManager(lambda: False, base_delay=0.1, max_delay=0.2,
                          max_attempts=3, clock=clock)
    rm.notify_failure()
    rm.notify_failure()                  # pending event: not re-armed
    clock.advance(10.0)
    assert rm.attempts == 3 and not rm.connected
    rm.notify_failure()                  # burst spent: a new failure re-arms
    clock.advance(10.0)
    assert rm.attempts == 6


def test_reconnect_synchronous_mode_unchanged():
    calls = []
    rm = ReconnectManager(lambda: calls.append(1) or len(calls) >= 3)
    rm.notify_failure()                  # seed behaviour: runs inline
    assert rm.connected and len(calls) == 3


def test_reconnect_rejects_wall_clock():
    from repro.core.clock import SystemClock
    with pytest.raises(TypeError):
        ReconnectManager(clock=SystemClock())


# ------------------------------------------------------------- injector
def _pool_with_running(n=2):
    clock = VirtualClock()
    pool = ClonePool(clock=clock)
    pool.provision("main", n, state=CloneState.RUNNING)
    return clock, pool


def test_injector_kill_marks_dead_and_trips_breaker():
    clock, pool = _pool_with_running()
    sec = pool.running_secondaries()[0]
    sec.busy = True
    inj = FaultInjector(pool, [CloneFault(at=0.5, kind="kill")])
    inj.arm()
    inj.arm()                            # idempotent
    assert inj.next_event_time() == 0.5
    clock.advance(1.0)
    assert sec.health is CloneHealth.DEAD
    assert sec.state is CloneState.POWERED_OFF
    assert sec.breaker.state == "open"
    assert not sec.serveable
    assert inj.stats == {"injected": 1, "kills": 1, "drains": 0,
                         "slowdowns": 0, "misses": 0, "clone_recoveries": 0}
    failed = inj.drain_failed()
    assert len(failed) == 1 and failed[0][0] is sec
    assert inj.drain_failed() == []      # drained once
    assert inj.next_event_time() is None


def test_injector_revive_needs_probe_to_serve_again():
    clock, pool = _pool_with_running()
    sec = pool.running_secondaries()[0]
    sec.busy = True
    inj = FaultInjector(pool, [CloneFault(at=0.0, kind="kill",
                                          duration=2.0)])
    inj.arm()
    clock.advance(1.5)                   # probe at ~1.0 fails (still dead)
    assert sec.health is CloneHealth.DEAD
    clock.advance(1.0)                   # revival at 2.0: answers pings
    assert sec.health is CloneHealth.SUSPECT
    assert not sec.serveable             # breaker still open
    clock.advance(3.0)                   # next probe promotes it
    assert sec.health is CloneHealth.HEALTHY
    assert sec.breaker.state == "closed"
    assert inj.stats["clone_recoveries"] == 1


def test_injector_targets_lowest_cid_busy_secondary_and_cid_pin():
    clock, pool = _pool_with_running(3)
    secs = sorted(pool.running_secondaries(), key=lambda c: c.cid)
    secs[1].busy = secs[2].busy = True
    inj = FaultInjector(pool, [CloneFault(at=0.0),
                               CloneFault(at=1.0, cid=secs[2].cid)])
    inj.arm()
    clock.advance(0.1)
    assert secs[1].health is CloneHealth.DEAD     # busy beats idle
    assert secs[0].health is CloneHealth.HEALTHY
    clock.advance(1.0)
    assert secs[2].health is CloneHealth.DEAD     # cid pin


def test_injector_miss_when_no_target():
    clock, pool = _pool_with_running(1)
    sec = pool.running_secondaries()[0]
    inj = FaultInjector(pool, [CloneFault(at=0.0, kind="kill"),
                               CloneFault(at=1.0, kind="kill")])
    inj.arm()
    clock.advance(0.5)                   # idle secondary still killable
    assert sec.health is CloneHealth.DEAD
    clock.advance(1.0)                   # nothing healthy left: miss
    assert inj.stats["injected"] == 1 and inj.stats["misses"] == 1


def test_injector_slowdown_scales_dispatch_and_clears():
    clock, pool = _pool_with_running()
    sec = pool.running_secondaries()[0]
    sec.busy = True
    inj = FaultInjector(pool, [CloneFault(at=0.0, kind="slow",
                                          duration=5.0, factor=4.0)])
    inj.arm()
    clock.advance(0.1)
    assert sec.slowdown == 4.0
    disp = Dispatcher(pool, clock)
    t = disp.submit(sec, lambda: 1, (),
                    executor=lambda c, f, a: (f(*a), 0.05))
    assert t.venue_seconds == pytest.approx(0.2)  # 0.05 x 4
    clock.advance(5.1)
    assert sec.slowdown == 1.0
    t2 = disp.submit(sec, lambda: 1, (),
                     executor=lambda c, f, a: (f(*a), 0.05))
    assert t2.venue_seconds == pytest.approx(0.05)


def test_injector_rejects_unknown_kind_and_wall_clock():
    _, pool = _pool_with_running()
    with pytest.raises(ValueError):
        FaultInjector(pool, [CloneFault(at=0.0, kind="explode")])
    from repro.core.clock import SystemClock
    with pytest.raises(TypeError):
        FaultInjector(pool, [], clock=SystemClock())


def test_dispatcher_cancel_revokes_completion():
    clock, pool = _pool_with_running()
    sec = pool.running_secondaries()[0]
    disp = Dispatcher(pool, clock)
    t = disp.submit(sec, lambda: 42, (),
                    executor=lambda c, f, a: (f(*a), 0.5))
    assert disp.cancel(t)
    assert not disp.cancel(t)            # idempotent
    clock.advance(1.0)
    assert not t.done and t.cancelled
    t2 = disp.submit(sec, lambda: 42, (),
                     executor=lambda c, f, a: (f(*a), 0.5))
    disp.wait([t2])
    assert not disp.cancel(t2)           # too late: already completed


# -------------------------------------------------------------- serving
def assert_no_block_leak(handler):
    """Block conservation on every surviving KV pool: each block's
    refcount equals the number of slot-table references to it, and
    free + cached-free + live-referenced == every allocatable block."""
    for kv in handler._kv_pools.values():
        refs = np.zeros(kv.num_blocks, np.int64)
        for slot in range(kv.max_slots):
            for j in range(int(kv.n_blocks_of[slot])):
                refs[kv.tables[slot, j]] += 1
        live = set(np.nonzero(kv.ref)[0].tolist())
        for b in range(1, kv.num_blocks):
            assert kv.ref[b] == refs[b], \
                f"block {b}: ref {kv.ref[b]} != {refs[b]} table references"
        accounted = (set(kv._free_blocks) | set(kv._cached_free) | live)
        assert accounted == set(range(1, kv.num_blocks)), \
            "block leak: free+cached+live != all blocks"


def _chaos_handler(faults=None, hedge=0.0, backend=None, spare=True,
                   **kw):
    from repro.launch.serve import ClientHandler
    kw.setdefault("max_batch", 4)
    kw.setdefault("prompt_pad", 8)
    kw.setdefault("block_size", 4)
    kw.setdefault("max_secondaries", 3)
    kw.setdefault("decode_window", 2)
    h = ClientHandler(backend or th.FakeBackend(),
                      executor=lambda c, f, a: (f(*a), 0.05),
                      faults=faults, hedge_factor=hedge,
                      hedge_min_samples=4, **kw)
    if spare:
        h.pool.provision(h.clone_type, 1, state=CloneState.RUNNING)
    return h


def run_chaos_trace(faults=None, hedge=0.0, *, seed=0, n=12, rate=8.0,
                    backend=None, vocab=64, new_tokens=10):
    """Serve one seeded Poisson trace under a fault schedule; returns the
    observables the chaos tests and the Hypothesis twin both assert on.
    Deterministic: same (seed, faults, hedge) -> same dict."""
    h = _chaos_handler(faults=faults, hedge=hedge, backend=backend)
    reqs = poisson_arrivals(rate, n, seed=seed, prompt_len=8, vocab=vocab,
                            max_new_tokens=new_tokens, prefix_len=4)
    rep = h.run(reqs)
    assert_no_block_leak(h)
    return {
        "tokens": {c.rid: tuple(map(int, c.tokens))
                   for c in rep.completions},
        "served": len(rep.completions),
        "offered": n,
        "injected": rep.faults_injected,
        "migrated": rep.recoveries_migrated,
        "restored": rep.recoveries_restored,
        "breaker_opens": rep.breaker_opens,
        "hedges_fired": rep.hedges_fired,
        "hedge_wins": rep.hedge_wins,
        "makespan_s": rep.makespan_s,
        "p99_latency_s": rep.p99_latency_s,
        "cost_usd": rep.cost_usd,
    }


def test_chaos_drain_recovers_by_migration_token_identical():
    base = run_chaos_trace()
    assert base["injected"] == 0 and base["served"] == 12
    out = run_chaos_trace([CloneFault(at=0.5 * base["makespan_s"],
                                      kind="drain", duration=2.0)])
    assert out["injected"] == 1
    assert out["migrated"] >= 1          # KV moved to a survivor
    assert out["breaker_opens"] >= 1
    assert out["served"] == 12
    assert out["tokens"] == base["tokens"]


def test_chaos_kill_recovers_by_restore_token_identical():
    base = run_chaos_trace()
    out = run_chaos_trace([CloneFault(at=0.5 * base["makespan_s"],
                                      kind="kill", duration=2.0)])
    assert out["injected"] == 1
    assert out["restored"] >= 1          # re-prefilled on a survivor
    assert out["migrated"] == 0          # killed memory is not salvageable
    assert out["served"] == 12
    assert out["tokens"] == base["tokens"]


def test_chaos_permanent_kill_still_serves_everything():
    """duration=0: the clone never comes back; the remaining fleet must
    still complete every request."""
    base = run_chaos_trace()
    out = run_chaos_trace([CloneFault(at=0.5 * base["makespan_s"],
                                      kind="kill", duration=0.0)])
    assert out["served"] == 12 and out["tokens"] == base["tokens"]


def test_chaos_real_backend_both_paths_token_identical():
    """The real reduced LM backend: recovery must reproduce the exact
    KV-dependent decode continuation — migration moves real cache
    content across pools, restore re-prefills it — bit-identically."""
    backend = th._chunk_lm_backend()
    vocab = backend.cfg.vocab_size
    base = run_chaos_trace(backend=backend, vocab=vocab)
    assert base["served"] == 12
    drain = run_chaos_trace([CloneFault(at=0.5 * base["makespan_s"],
                                        kind="drain", duration=2.0)],
                            backend=backend, vocab=vocab)
    kill = run_chaos_trace([CloneFault(at=0.5 * base["makespan_s"],
                                       kind="kill", duration=2.0)],
                           backend=backend, vocab=vocab)
    assert drain["migrated"] >= 1 and kill["restored"] >= 1
    assert drain["tokens"] == base["tokens"]
    assert kill["tokens"] == base["tokens"]


def test_hedged_dispatch_wins_race_and_bills_once():
    base = run_chaos_trace()
    span = base["makespan_s"]
    slow = lambda: [CloneFault(at=0.6 * span, kind="slow",  # noqa: E731
                               duration=0.4 * span, factor=40.0)]
    unhedged = run_chaos_trace(slow())
    hedged = run_chaos_trace(slow(), hedge=2.0)
    assert unhedged["hedges_fired"] == 0
    assert hedged["hedges_fired"] >= 1 and hedged["hedge_wins"] >= 1
    # the winner's tokens are used and identical to the straggler's
    assert hedged["tokens"] == unhedged["tokens"] == base["tokens"]
    # the race bounds the straggler's tail latency
    assert hedged["p99_latency_s"] < unhedged["p99_latency_s"]
    # no double-billing: the $-meter runs on clone-seconds, and racing a
    # duplicate on an already-running spare must not inflate the bill
    # beyond the unhedged run's (shorter makespan: it can only shrink)
    assert hedged["cost_usd"] <= unhedged["cost_usd"] + 1e-9


def test_hedge_loser_is_cancelled():
    """Count live dispatch events: every submitted task either completed
    or was cancelled — a lost hedge must not fire its completion."""
    base = run_chaos_trace()
    span = base["makespan_s"]
    h = _chaos_handler(faults=[CloneFault(at=0.6 * span, kind="slow",
                                          duration=0.4 * span,
                                          factor=40.0)], hedge=2.0)
    submitted = []
    orig = h.dispatcher.submit

    def spy(*a, **k):
        t = orig(*a, **k)
        submitted.append(t)
        return t

    h.dispatcher.submit = spy
    reqs = poisson_arrivals(8.0, 12, seed=0, prompt_len=8, vocab=64,
                            max_new_tokens=10, prefix_len=4)
    h.run(reqs)
    assert h.hedges_fired >= 1
    hedges = [t for t in submitted if t.label == "hedge"]
    assert hedges, "no hedge task submitted"
    for t in submitted:
        assert t.done or t.cancelled, f"task {t.label!r} left dangling"
    # every resolved race cancelled exactly one of the pair
    cancelled = sum(t.cancelled for t in submitted)
    assert cancelled >= len(hedges) \
        or h.hedge_wins == len(hedges)   # losers were the originals


def test_faults_require_paged_kv():
    from repro.launch.serve import ClientHandler
    with pytest.raises(ValueError):
        ClientHandler(th.FakeBackend(), kv="contiguous",
                      faults=[CloneFault(at=1.0)],
                      executor=lambda c, f, a: (f(*a), 0.05))
    with pytest.raises(ValueError):
        ClientHandler(th.FakeBackend(), kv="contiguous", hedge_factor=2.0,
                      executor=lambda c, f, a: (f(*a), 0.05))


# --------------------------------------------------------------------------- #
# speculative decoding under faults (ADR-008): the draft tier is
# sacrificial — killing it degrades the engine, never the stream
# --------------------------------------------------------------------------- #
def _run_spec_chaos(faults=None, *, speculative=True, seed=0, n=12):
    h = _chaos_handler(faults=faults, backend=th.SpecFakeBackend(),
                       speculative=speculative, spec_k=4)
    reqs = poisson_arrivals(8.0, n, seed=seed, prompt_len=8, vocab=64,
                            max_new_tokens=10, prefix_len=4)
    rep = h.run(reqs)
    assert_no_block_leak(h)
    return h, rep


def test_chaos_spec_draft_kill_degrades_token_identical():
    """Kill the draft clone mid-decode: the interrupted round completes
    as a zero-draft verify on the healthy target, the engine stickily
    degrades to plain decode, and every stream stays bitwise identical
    to the non-speculative baseline — a dead draft tier costs speedup,
    never tokens, and never a stall."""
    _, plain = _run_spec_chaos(speculative=False)
    base_tokens = {c.rid: tuple(map(int, c.tokens))
                   for c in plain.completions}
    h0, spec = _run_spec_chaos()
    assert {c.rid: tuple(map(int, c.tokens))
            for c in spec.completions} == base_tokens
    assert spec.spec_rounds > 0 and h0.spec_draft_cids
    # same seeded trace -> same pairing order -> same draft cid
    out_h, out = _run_spec_chaos(
        [CloneFault(at=0.5 * spec.makespan_s, kind="kill", duration=0.0,
                    cid=h0.spec_draft_cids[0])])
    assert {c.rid: tuple(map(int, c.tokens))
            for c in out.completions} == base_tokens
    assert len(out.completions) == 12
    assert out.faults_injected == 1
    assert out.spec_fallbacks >= 1          # the engine really degraded
    assert 0 < out.spec_rounds <= spec.spec_rounds
    # only the draft died: no engine requests were lost or moved
    assert out.recoveries_migrated == 0
    assert out.recoveries_restored == 0
