"""ThinkAir core behaviour: policies, clone pool, controller, parallelizer,
faults, energy — the paper's §4-§6 semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CLONE_TYPES, ClonePool, CloneState,
                        ExecutionController, FaultPlan, Parallelizer,
                        PhoneState, Policy, PowerTutorModel, Prediction,
                        RemoteableMethod, TpuEnergyModel, VenueFailure,
                        resume_time, should_offload, split_batch,
                        split_range)
from repro.core.clones import BOOT_SECONDS, RESUME_SECONDS


# --------------------------------------------------------------------------- #
# policies
# --------------------------------------------------------------------------- #
def test_policy_semantics():
    fast_cheap = Prediction(1.0, 1.0)
    slow_dear = Prediction(2.0, 2.0)
    fast_dear = Prediction(1.0, 3.0)
    assert not should_offload(Policy.NONE, slow_dear, fast_cheap)
    assert should_offload(Policy.EXEC_TIME, slow_dear, fast_cheap)
    assert should_offload(Policy.EXEC_TIME, slow_dear, fast_dear)
    assert not should_offload(Policy.ENERGY, slow_dear, fast_dear)
    assert should_offload(Policy.EXEC_TIME_AND_ENERGY, slow_dear, fast_cheap)
    assert not should_offload(Policy.EXEC_TIME_AND_ENERGY, slow_dear,
                              fast_dear)


def test_placement_key_orders_candidates_by_policy():
    """The fleet placement scorer (ADR-004): NONE ranks by $, EXEC_TIME by
    provisioning latency, ENERGY by energy rate, BOTH by the energy-delay
    product — each a total order (ties broken by the other quantities)."""
    from repro.core import placement_key
    cheap_slow = Prediction(time_s=32.0, energy_j=600.0, cost_usd=0.01)
    dear_fast = Prediction(time_s=0.3, energy_j=2350.0, cost_usd=0.10)
    pk = placement_key
    assert pk(Policy.NONE, cheap_slow) < pk(Policy.NONE, dear_fast)
    assert pk(Policy.EXEC_TIME, dear_fast) < pk(Policy.EXEC_TIME, cheap_slow)
    assert pk(Policy.ENERGY, cheap_slow) < pk(Policy.ENERGY, dear_fast)
    # energy-delay: the horizon-inclusive delay keeps a *warm* power-hungry
    # tier from degenerating to a free win (0 x anything) — a paused cheap
    # tier still beats it for bulk
    warm_dear = Prediction(time_s=0.0, energy_j=2350.0, cost_usd=0.10)
    paused_cheap = Prediction(time_s=0.3, energy_j=600.0, cost_usd=0.01)
    both = Policy.EXEC_TIME_AND_ENERGY
    assert pk(both, paused_cheap) < pk(both, warm_dear)
    assert pk(both, warm_dear) < pk(both, Prediction(0.3, 2350.0, 0.10))


# --------------------------------------------------------------------------- #
# energy models
# --------------------------------------------------------------------------- #
def test_powertutor_paper_coefficients():
    m = PowerTutorModel()
    # full-load phone: CPU at 100% high freq + screen (paper Table 2)
    comps = m.power_mw(PhoneState(cpu_util=100.0, brightness=150))
    assert comps["cpu"] == pytest.approx(4.32 * 100 + 121.46)
    assert comps["screen"] == pytest.approx(2.40 * 150)
    # 3G DCH state = 570 mW, FACH = 401 mW, idle = 10 mW
    assert m.power_mw(PhoneState(cell="dch"))["3g"] == 570.0
    assert m.power_mw(PhoneState(cell="fach"))["3g"] == 401.0
    assert m.power_mw(PhoneState(cell="idle"))["3g"] == 10.0
    # WiFi high/low
    assert m.power_mw(PhoneState(wifi="high"))["wifi"] == 710.0
    assert m.power_mw(PhoneState(wifi="low"))["wifi"] == 20.0


def test_energy_linear_in_time():
    m = PowerTutorModel()
    st = PhoneState(cpu_util=50.0)
    e1 = sum(m.energy_j(st, 1.0).values())
    e2 = sum(m.energy_j(st, 2.0).values())
    assert e2 == pytest.approx(2 * e1)


def test_tpu_energy_components():
    m = TpuEnergyModel()
    e = m.energy_j(chips=4, seconds=2.0, util=1.0, hbm_bytes=1e9,
                   ici_bytes=1e9)
    assert e["chips"] == pytest.approx(4 * 250.0 * 2.0)
    assert e["hbm"] > 0 and e["ici"] > 0


# --------------------------------------------------------------------------- #
# clone pool (paper §5.3)
# --------------------------------------------------------------------------- #
def test_tpu_clone_types_cover_every_clone_type():
    """Regression (ISSUE 4 satellite): the TPU fleet mapping is explicit
    per CloneType — the old ``tpu-{cpus}`` lookup silently fell back to
    the raw CPU count for x2large/x8large (no ``tpu-2``/``tpu-8`` entries)
    and could never provision ``tpu-pod``/``tpu-2pod`` sub-meshes."""
    from repro.core.clones import TPU_BY_CLONE_TYPE, TPU_CLONE_TYPES
    assert set(TPU_BY_CLONE_TYPE) == set(CLONE_TYPES)
    assert all(v in TPU_CLONE_TYPES for v in TPU_BY_CLONE_TYPE.values())
    pool = ClonePool(tpu=True)
    chips_by_type = {}
    for name in CLONE_TYPES:
        clone = pool.provision(name, 1)[0]
        chips_by_type[name] = clone.spec.chips
        assert clone.spec.name == TPU_BY_CLONE_TYPE[name]
        assert clone.spec.chips == TPU_CLONE_TYPES[TPU_BY_CLONE_TYPE[name]]
    # the escalation ladder (OOM handling) reaches the pod tiers
    assert chips_by_type["x8large"] == 512     # was 8 under the cpus key
    order = [chips_by_type[t.name]
             for t in sorted(CLONE_TYPES.values(), key=lambda t: t.rank())]
    assert order == sorted(order)              # chips grow with escalation


def test_clone_pool_primary_always_running():
    pool = ClonePool()
    assert pool.primary.state is CloneState.RUNNING
    pool.pause(pool.primary)           # primary may not pause
    assert pool.primary.state is CloneState.RUNNING


def test_resume_costs_match_paper_observations():
    # 1 resume ~300 ms; 7 simultaneous -> 6-7 s (paper §5.3)
    assert resume_time(1) == pytest.approx(0.300)
    assert 6.0 <= resume_time(7) <= 7.0
    assert BOOT_SECONDS == 32.0


def test_acquire_prefers_paused_over_boot():
    t = [0.0]
    pool = ClonePool(clock=lambda: t[0])
    pool.provision("main", 3)          # paused secondaries
    clones, cost = pool.acquire("main", n=3, exclude_primary=True)
    assert len(clones) == 3
    assert cost == pytest.approx(resume_time(3))
    assert pool.stats["boots"] == 0
    pool.release(clones)
    # cold acquire of a type with no paused clones -> boot cost
    clones2, cost2 = pool.acquire("x4large", n=1)
    assert cost2 == BOOT_SECONDS
    assert pool.stats["boots"] == 1


def test_idle_reaping_pause_then_off():
    t = [0.0]
    pool = ClonePool(clock=lambda: t[0])
    clones, _ = pool.acquire("main", n=2, exclude_primary=True)
    pool.release(clones)
    t[0] = 31.0
    pool.reap_idle()
    assert all(c.state is CloneState.PAUSED for c in clones)
    t[0] = 31.0 + 601.0
    pool.reap_idle()
    assert all(c.state is CloneState.POWERED_OFF for c in clones)


def test_escalation_chain_reaches_most_powerful():
    pool = ClonePool()
    chain = ["basic"]
    while True:
        nxt = pool.escalate_type(chain[-1])
        if nxt is None:
            break
        chain.append(nxt)
    assert chain[-1] == "x8large"
    assert len(chain) == len(CLONE_TYPES)


def test_escalate_type_top_tier_returns_none():
    """ISSUE 5 satellite: the ladder ends explicitly — the top tier has no
    successor and callers must degrade gracefully, not walk off the end."""
    pool = ClonePool()
    assert pool.escalate_type("x8large") is None


def test_clone_type_rank_total_order():
    """ISSUE 5 satellite: ``CloneType.rank`` totally orders all six paper
    types — every rank distinct, and sorting by rank reproduces the
    paper's escalation ladder exactly."""
    ranks = {name: t.rank() for name, t in CLONE_TYPES.items()}
    assert len(set(ranks.values())) == len(CLONE_TYPES)   # total order
    ladder = sorted(CLONE_TYPES, key=lambda n: CLONE_TYPES[n].rank())
    assert ladder == ["basic", "main", "large", "x2large", "x4large",
                      "x8large"]
    assert all(a < b for a, b in
               zip([ranks[n] for n in ladder], [ranks[n] for n in ladder][1:]))


def test_usd_pricing_and_kv_scale_follow_the_ladder():
    """$-rates and KV capacity multipliers grow strictly with escalation
    rank, so 'bigger tier' always means 'dearer and roomier'."""
    from repro.core.clones import (KV_SCALE_BY_CLONE_TYPE, usd_per_second)
    ladder = sorted(CLONE_TYPES, key=lambda n: CLONE_TYPES[n].rank())
    usd = [usd_per_second(n) for n in ladder]
    kv = [KV_SCALE_BY_CLONE_TYPE[n] for n in ladder]
    assert all(a < b for a, b in zip(usd, usd[1:]))
    assert all(a < b for a, b in zip(kv, kv[1:]))


def test_clone_running_seconds_accrue_and_stop_on_pause():
    """$-accounting (ADR-004): clone-seconds accrue while RUNNING (idle
    included) and stop on pause/power-off; ``cost_usd`` bills them at the
    per-type rate (primary's standing cost included)."""
    from repro.core.clones import usd_per_second
    t = [0.0]
    pool = ClonePool(clock=lambda: t[0])
    clones, _ = pool.acquire("large", n=1, exclude_primary=True)
    t[0] = 10.0
    pool.release(clones)
    by_type = pool.clone_seconds_by_type()
    assert by_type["large"] == pytest.approx(10.0)   # live interval
    assert by_type["main"] == pytest.approx(10.0)    # always-on primary
    pool.pause(clones[0])
    t[0] = 25.0
    by_type = pool.clone_seconds_by_type()
    assert by_type["large"] == pytest.approx(10.0)   # stopped at pause
    assert by_type["main"] == pytest.approx(25.0)
    assert pool.cost_usd() == pytest.approx(
        10.0 * usd_per_second("large") + 25.0 * usd_per_second("main"))


# --------------------------------------------------------------------------- #
# controller (paper §4.3-4.4)
# --------------------------------------------------------------------------- #
def _method(heavy=False):
    n = 2_000_000 if heavy else 100

    def fn(x):
        y = x
        for _ in range(3):
            y = jnp.tanh(y @ y.T) @ y if heavy else y + 1
        return y.sum()

    return RemoteableMethod(f"m{heavy}", fn, size_fn=lambda x: x.size)


def test_first_encounter_env_only():
    ec = ExecutionController(policy=Policy.EXEC_TIME, link="wifi-local")
    rm = _method()
    x = jnp.ones((8, 8))
    res = ec.execute(rm, x)
    assert res.offloaded          # good connectivity => offload unknown method
    ec2 = ExecutionController(policy=Policy.EXEC_TIME, link="wifi-local")
    ec2.device.observe(connectivity="none")
    res2 = ec2.execute(rm, x)
    assert not res2.offloaded     # no connectivity => local


def test_policy_none_never_offloads():
    ec = ExecutionController(policy=Policy.NONE)
    rm = _method()
    for _ in range(3):
        assert not ec.execute(rm, jnp.ones((4, 4))).offloaded


def test_fault_falls_back_to_local_and_reconnects():
    ec = ExecutionController(policy=Policy.EXEC_TIME,
                             fault_plan=FaultPlan(fail_next=1))
    rm = _method()
    res = ec.execute(rm, jnp.ones((4, 4)), force="remote")
    assert res.fell_back and res.venue == "phone"
    assert ec.reconnect.connected          # async reconnection completed
    assert ec.decisions["fallback"] == 1


def test_oom_escalation_to_bigger_clone():
    """Image-combiner scenario: working set exceeds the default clone."""
    ec = ExecutionController(policy=Policy.EXEC_TIME)
    big = 800 * 2 ** 20                    # needs > main's 512 MB
    rm = RemoteableMethod("combiner", lambda x: x * 2,
                          size_fn=lambda x: x.size,
                          mem_fn=lambda x: big)
    res = ec.execute(rm, jnp.ones((16, 16)), force="remote")
    assert res.escalations >= 1
    assert res.venue in ("large", "x2large", "x4large", "x8large")


def test_history_driven_decision_prefers_faster_venue():
    ec = ExecutionController(policy=Policy.EXEC_TIME, link="3g")
    rm = _method()                         # trivial method, slow 3G link
    x = jnp.ones((4, 4))
    ec.execute(rm, x, force="local")
    ec.execute(rm, x, force="remote")
    res = ec.execute(rm, x)                # trivial compute + 3G => local
    assert not res.offloaded


def test_transfer_bytes_accounted():
    ec = ExecutionController()
    rm = _method()
    x = jnp.ones((64, 64), jnp.float32)
    res = ec.execute(rm, x, force="remote")
    assert res.tx_bytes >= x.size * 4
    assert res.rx_bytes > 0
    assert res.overhead_s > 0


# --------------------------------------------------------------------------- #
# parallelizer (paper §7.4) + stragglers
# --------------------------------------------------------------------------- #
def test_split_batch_and_range():
    shards = split_batch((np.arange(10),), 3)
    assert [s[0].shape[0] for s in shards] == [4, 3, 3]
    assert split_range(0, 8, 4) == [(0, 2), (2, 4), (4, 6), (6, 8)]


def test_parallel_makespan_includes_resume_and_sync():
    pool = ClonePool()
    pool.provision("main", 4)
    par = Parallelizer(pool)
    fn = lambda x: x.sum()
    shards = split_batch((jnp.arange(32.0),), 4)
    res = par.run(fn, shards, merge=lambda vs: sum(float(v) for v in vs))
    assert res.n_clones == 4
    assert res.resume_s > 0                      # resumed paused clones
    assert res.sync_s == pytest.approx(0.05 * 3)
    assert res.makespan_s >= max(res.shard_times)
    assert res.value == pytest.approx(float(jnp.arange(32.0).sum()))


def test_straggler_redispatch():
    pool = ClonePool()
    pool.provision("main", 6)
    par = Parallelizer(pool, straggler_factor=2.0)
    fn = lambda x: x.sum()
    shards = split_batch((jnp.arange(16.0),), 4)
    res = par.run(fn, shards, merge=lambda vs: vs,
                  shard_delays=[0.0, 0.0, 0.0, 100.0])
    assert res.redispatches == 1
    assert max(res.shard_times) < 100.0          # rescue beat the straggler
