"""Docs stay valid: intra-repo links resolve, code snippets execute, and
the ServeReport.summary() format shown in docs/benchmarks.md matches the
implementation (the docs are tier-1, not decoration)."""
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_markdown_links_resolve():
    bad = []
    for md in check_docs.doc_files([]):
        bad += check_docs.check_links(md)
    assert not bad, f"broken intra-repo links: {bad}"


def test_docs_code_snippets_run():
    bad = []
    for md in check_docs.doc_files([]):
        bad += check_docs.check_doctests(md)
    assert not bad, f"doctest failures in docs: {bad}"


def test_docs_exist_and_cover_the_stack():
    arch = (REPO / "docs" / "architecture.md").read_text()
    for layer in ("VirtualClock", "Dispatcher", "ClonePool", "ClientHandler",
                  "SlotLedger", "KVBlockPool"):
        assert layer in arch, f"architecture.md misses {layer}"
    bench = (REPO / "docs" / "benchmarks.md").read_text()
    for metric in ("ttft", "kv_util", "busy_J", "BENCH_serving.json"):
        assert metric in bench, f"benchmarks.md misses {metric}"


def test_serve_report_summary_matches_docs_format():
    """The summary line shown in docs/benchmarks.md must be exactly what
    ServeReport.summary() produces for those values."""
    from repro.launch.serve import ServeReport

    rep = ServeReport(
        completions=[None] * 32, accepted=32, rejected=0, makespan_s=8.7,
        p50_latency_s=0.211, p99_latency_s=0.334, p50_ttft_s=0.035,
        tokens_per_s=22.0, peak_secondaries=1, scale_ups=1,
        busy_energy_j=149.0, pool_stats={}, clone_samples=[],
        kv_mode="paged", kv_util=0.75, kv_reserved_peak=64)
    line = rep.summary()
    bench = (REPO / "docs" / "benchmarks.md").read_text()
    assert line in bench, (
        f"docs/benchmarks.md does not show the real summary() format:\n"
        f"{line}")
    # and the format carries every headline quantity
    for frag in ("served=32", "p99=0.334s", "ttft50=0.035s", "kv_util=75%"):
        assert frag in line


def test_readme_links_docs():
    readme = (REPO / "README.md").read_text()
    assert re.search(r"docs/architecture\.md", readme)
    assert re.search(r"docs/benchmarks\.md", readme)
