"""Per-kernel allclose vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,d,causal,window,softcap",
    [
        (1, 64, 2, 2, 32, True, None, None),
        (2, 128, 4, 1, 64, True, None, None),       # GQA 4:1
        (2, 128, 6, 2, 32, True, 32, None),         # sliding window
        (1, 96, 3, 3, 80, True, None, 30.0),        # softcap, unaligned d
        (1, 128, 2, 2, 16, False, None, None),      # encoder (non-causal)
        (2, 72, 5, 5, 24, True, None, None),        # unaligned seq (padding)
    ])
def test_flash_attention_matches_ref(b, s, hq, hkv, d, causal, window,
                                     softcap, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, s, hq, d), dtype)
    k = _rand(k2, (b, s, hkv, d), dtype)
    v = _rand(k3, (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, bq=32, bk=32, interpret=True)
    want = ref.flash_attention_ref(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, window=window, softcap=softcap).swapaxes(1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_block_size_invariance():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (1, 128, 2, 32), jnp.float32)
    k = _rand(k2, (1, 128, 2, 32), jnp.float32)
    v = _rand(k3, (1, 128, 2, 32), jnp.float32)
    outs = [np.asarray(ops.flash_attention(q, k, v, bq=bq, bk=bk,
                                           interpret=True))
            for bq, bk in [(32, 32), (64, 32), (128, 64), (128, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------- #
# paged attention (decode-time block-table gather)
# --------------------------------------------------------------------------- #
def _paged_case(key, b, hq, hkv, d, bs, lens, dtype=jnp.float32, seed=0):
    """Random pool + a block table giving each slot distinct blocks."""
    max_blk = max(-(-ln // bs) for ln in lens)
    n_blocks = sum(-(-ln // bs) for ln in lens) + 1      # block 0 = trash
    k1, k2, k3 = jax.random.split(key, 3)
    q = _rand(k1, (b, hq, d), dtype)
    kp = _rand(k2, (n_blocks, bs, hkv, d), dtype)
    vp = _rand(k3, (n_blocks, bs, hkv, d), dtype)
    tables = np.zeros((b, max_blk), np.int32)
    nxt = 1
    for i, ln in enumerate(lens):
        for j in range(-(-ln // bs)):
            tables[i, j] = nxt
            nxt += 1
    return q, kp, vp, jnp.asarray(tables), jnp.asarray(np.asarray(lens,
                                                                  np.int32))


@pytest.mark.parametrize("fused", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,d,bs,lens,softcap", [
    (2, 4, 2, 32, 8, (5, 16), None),            # GQA 2:1, ragged lengths
    (3, 6, 2, 32, 8, (1, 17, 32), None),        # boundary + full block
    (1, 3, 3, 16, 4, (11,), 20.0),              # softcap, MHA (group 1)
    (2, 8, 2, 16, 4, (3, 9), None),             # GQA 4:1
])
def test_paged_attention_kernel_matches_ref(b, hq, hkv, d, bs, lens, softcap,
                                            dtype, fused):
    q, kp, vp, tables, cls = _paged_case(KEY, b, hq, hkv, d, bs, lens, dtype)
    got = ops.paged_attention(q[:, None], kp, vp, tables, cls,
                              softcap=softcap, fused=fused, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, tables, cls, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got[:, 0], np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("hq,hkv", [(2, 2), (4, 2), (8, 2)])   # groups 1/2/4
def test_paged_attention_fused_matches_per_head_kernel(hq, hkv):
    """The GQA-fused flash-decoding grid computes exactly what the per-head
    grid computes — fusion only changes KV staging, never the math — and it
    stages each block g x fewer times (the fetch accounting the benchmark
    reports)."""
    from repro.kernels.flash_attention import paged_kv_fetches
    lens = (5, 13, 24)
    q, kp, vp, tables, cls = _paged_case(KEY, 3, hq, hkv, 16, 8, lens)
    fused = ops.paged_attention(q[:, None], kp, vp, tables, cls,
                                fused=True, interpret=True)
    unfused = ops.paged_attention(q[:, None], kp, vp, tables, cls,
                                  fused=False, interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=2e-6, rtol=2e-6)
    g = hq // hkv
    m = tables.shape[1]
    assert paged_kv_fetches(3, hq, hkv, m, fused=False) == \
        g * paged_kv_fetches(3, hq, hkv, m, fused=True)


def test_paged_attention_matches_contiguous_cache():
    """Gathering a slot's blocks through the table == attending over the
    same KV stored contiguously (the paged/contiguous equivalence that the
    serving layer relies on for token-identical mid-flight joins)."""
    lens = (5, 12, 8)
    q, kp, vp, tables, cls = _paged_case(KEY, 3, 4, 2, 32, 4, lens)
    got = ref.paged_attention_ref(q, kp, vp, tables, cls)
    for i, ln in enumerate(lens):
        nb = -(-ln // 4)
        kc = np.asarray(kp)[np.asarray(tables)[i, :nb]].reshape(-1, 2, 32)
        vc = np.asarray(vp)[np.asarray(tables)[i, :nb]].reshape(-1, 2, 32)
        want = ref.flash_attention_ref(
            q[i:i + 1, :, None],
            jnp.swapaxes(jnp.asarray(kc[None, :ln]), 1, 2),
            jnp.swapaxes(jnp.asarray(vc[None, :ln]), 1, 2),
            causal=False)[:, :, 0]
        np.testing.assert_allclose(np.asarray(got[i:i + 1]),
                                   np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("fused", [True, False])
def test_paged_attention_ignores_stale_pool_contents(fused):
    """Positions past a slot's context length — the unwritten tail *inside*
    an allocated block, and the whole trash block — must never leak into
    its output, whatever garbage they hold."""
    q, kp, vp, tables, cls = _paged_case(KEY, 2, 2, 2, 16, 4, (3, 7))
    out0 = ops.paged_attention(q[:, None], kp, vp, tables, cls,
                               fused=fused, interpret=True)
    poisoned_k = kp.at[0].set(1e9)               # trash block
    poisoned_v = vp.at[0].set(-1e9)
    # unwritten tail inside allocated blocks: slot 0 (ctx 3) owns block 1,
    # its position 3 is unwritten; slot 1 (ctx 7) owns blocks 2,3 — block
    # 3's position 7 (offset 3) is unwritten
    blk0 = int(np.asarray(tables)[0, 0])
    blk1 = int(np.asarray(tables)[1, 1])
    poisoned_k = poisoned_k.at[blk0, 3].set(1e9).at[blk1, 3].set(1e9)
    poisoned_v = poisoned_v.at[blk0, 3].set(-1e9).at[blk1, 3].set(-1e9)
    out1 = ops.paged_attention(q[:, None], poisoned_k, poisoned_v, tables,
                               cls, fused=fused, interpret=True)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1))
    # same invariant for the reference oracle
    ref0 = ref.paged_attention_ref(q, kp, vp, tables, cls)
    ref1 = ref.paged_attention_ref(q, poisoned_k, poisoned_v, tables, cls)
    np.testing.assert_allclose(np.asarray(ref0), np.asarray(ref1))


@pytest.mark.parametrize("fused", [True, False])
def test_paged_attention_zero_context_slot_outputs_zero(fused):
    """A context_lens==0 row (empty/inactive slot) must output exact zeros
    in both kernel grids AND the oracle — not a softmax over garbage."""
    q, kp, vp, tables, cls = _paged_case(KEY, 2, 4, 2, 16, 4, (7, 8))
    cls = cls.at[1].set(0)
    want = np.asarray(ref.paged_attention_ref(q, kp, vp, tables, cls))
    np.testing.assert_array_equal(want[1], 0.0)
    got = np.asarray(ops.paged_attention(q[:, None], kp, vp, tables, cls,
                                         fused=fused, interpret=True))
    np.testing.assert_array_equal(got[1], 0.0)
    np.testing.assert_allclose(got[:, 0], want, atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# paged prefill (chunked suffix attention through the block table, ADR-005)
# --------------------------------------------------------------------------- #
def _prefill_case(key, b, hq, hkv, d, bs, c, pos0, dtype=jnp.float32):
    """Random pool + tables covering each slot's pos0 + c positions."""
    spans = [-(-(p + c) // bs) for p in pos0]
    max_blk = max(spans)
    n_blocks = sum(spans) + 1                    # block 0 = trash
    k1, k2, k3 = jax.random.split(key, 3)
    q = _rand(k1, (b, c, hq, d), dtype)
    kp = _rand(k2, (n_blocks, bs, hkv, d), dtype)
    vp = _rand(k3, (n_blocks, bs, hkv, d), dtype)
    tables = np.zeros((b, max_blk), np.int32)
    nxt = 1
    for i, nb in enumerate(spans):
        for j in range(nb):
            tables[i, j] = nxt
            nxt += 1
    return q, kp, vp, jnp.asarray(tables), jnp.asarray(np.asarray(pos0,
                                                                  np.int32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,d,bs,c,pos0,n_live,softcap", [
    (2, 4, 2, 32, 8, 8, (0, 11), (8, 5), None),    # GQA 2:1, ragged chunks
    (3, 6, 2, 16, 8, 4, (5, 0, 8), (4, 0, 1), None),  # dead slot + boundary
    (1, 3, 3, 16, 4, 8, (3,), (6,), 20.0),         # softcap, MHA (group 1)
    (2, 8, 2, 16, 4, 1, (7, 2), (1, 1), None),     # C=1 degenerates to decode
])
def test_paged_prefill_kernel_matches_ref(b, hq, hkv, d, bs, c, pos0, n_live,
                                          softcap, dtype):
    q, kp, vp, tables, p0 = _prefill_case(KEY, b, hq, hkv, d, bs, c, pos0,
                                          dtype)
    nl = jnp.asarray(np.asarray(n_live, np.int32))
    got = ops.paged_prefill(q, kp, vp, tables, p0, nl, softcap=softcap,
                            interpret=True)
    want = ref.paged_prefill_ref(q.swapaxes(1, 2), kp, vp, tables, p0, nl,
                                 softcap=softcap).swapaxes(1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)
    # rows at chunk positions >= n_live (bucket padding) are exact zeros
    got_np = np.asarray(got, np.float32)
    for i, n in enumerate(n_live):
        np.testing.assert_array_equal(got_np[i, n:], 0.0)


def test_paged_prefill_matches_per_token_decode():
    """Chunk row t must equal the decode kernel's output for the same query
    at context length pos0+t+1 on the same pool — the equivalence that makes
    chunked prefill token-identical to the stepwise scan."""
    b, hq, hkv, d, bs, c = 2, 4, 2, 16, 4, 6
    pos0, n_live = (3, 8), (6, 4)
    q, kp, vp, tables, p0 = _prefill_case(KEY, b, hq, hkv, d, bs, c, pos0)
    nl = jnp.asarray(np.asarray(n_live, np.int32))
    chunk_out = np.asarray(ops.paged_prefill(q, kp, vp, tables, p0, nl,
                                             interpret=True))
    for t in range(c):
        lens = jnp.asarray([(p + t + 1) if t < n else 0
                            for p, n in zip(pos0, n_live)], jnp.int32)
        tok = ops.paged_attention(q[:, t:t + 1], kp, vp, tables, lens,
                                  interpret=True)
        np.testing.assert_allclose(chunk_out[:, t], np.asarray(tok[:, 0]),
                                   atol=2e-5, rtol=2e-5)


def test_paged_prefill_ignores_stale_pool_contents():
    """Key positions past pos0 + t (not yet written at chunk position t in
    the stepwise order) and the trash block must never leak into the
    output, whatever garbage they hold."""
    b, hq, hkv, d, bs, c = 2, 4, 2, 16, 4, 4
    pos0, n_live = (2, 5), (4, 3)
    q, kp, vp, tables, p0 = _prefill_case(KEY, b, hq, hkv, d, bs, c, pos0)
    nl = jnp.asarray(np.asarray(n_live, np.int32))
    out0 = ops.paged_prefill(q, kp, vp, tables, p0, nl, interpret=True)
    pk, pv = kp.at[0].set(1e9), vp.at[0].set(-1e9)       # trash block
    # poison every pool position past each slot's last live key
    tb = np.asarray(tables)
    for i, (p, n) in enumerate(zip(pos0, n_live)):
        for pos in range(p + n, tb.shape[1] * bs):
            blk, off = tb[i, pos // bs], pos % bs
            pk = pk.at[blk, off].set(1e9)
            pv = pv.at[blk, off].set(-1e9)
    out1 = ops.paged_prefill(q, pk, pv, tables, p0, nl, interpret=True)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1))
    ref0 = ref.paged_prefill_ref(q.swapaxes(1, 2), kp, vp, tables, p0, nl)
    ref1 = ref.paged_prefill_ref(q.swapaxes(1, 2), pk, pv, tables, p0, nl)
    np.testing.assert_allclose(np.asarray(ref0), np.asarray(ref1))


# --------------------------------------------------------------------------- #
# rglru scan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s,r,bs", [
    (1, 64, 32, 16), (2, 100, 96, 32), (3, 256, 128, 256), (1, 8, 16, 8),
])
def test_rglru_scan_matches_ref(b, s, r, bs):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, r)))
    bb = jax.random.normal(k2, (b, s, r))
    h0 = jax.random.normal(k3, (b, r))
    y, hn = ops.rglru_scan(a, bb, h0, bs=bs, interpret=True)
    yr, hnr = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hn), np.asarray(hnr), atol=1e-5,
                               rtol=1e-4)


def test_rglru_scan_zero_init():
    k1, k2 = jax.random.split(KEY)
    a = jax.nn.sigmoid(jax.random.normal(k1, (2, 32, 16)))
    bb = jax.random.normal(k2, (2, 32, 16))
    y, hn = ops.rglru_scan(a, bb, None, bs=8, interpret=True)
    yr, hnr = ref.rglru_scan_ref(a, bb, jnp.zeros((2, 16)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-4)


# --------------------------------------------------------------------------- #
# rwkv6 scan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s,h,n,chunk", [
    (1, 64, 2, 16, 16), (2, 96, 3, 32, 32), (1, 40, 1, 8, 16),
])
def test_rwkv6_scan_matches_ref(b, s, h, n, chunk):
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, n))) * 0.7 + 0.29
    u = jax.random.normal(ks[4], (h, n))
    s0 = jax.random.normal(ks[5], (b, h, n, n))
    y, sn = ops.rwkv6_scan(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    yr, snr = ref.rwkv6_scan_ref(r.swapaxes(1, 2), k.swapaxes(1, 2),
                                 v.swapaxes(1, 2), w.swapaxes(1, 2), u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr.swapaxes(1, 2)),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sn), np.asarray(snr), atol=2e-4,
                               rtol=1e-3)


def test_rwkv6_model_chunked_matches_sequential_oracle():
    """The model's chunked-parallel WKV == the kernel's sequential oracle."""
    from repro.models.rwkv6 import wkv6_chunked_ref
    ks = jax.random.split(KEY, 6)
    b, s, h, n = 2, 64, 2, 16
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, n))) * 0.7 + 0.29
    u = jax.random.normal(ks[4], (h, n))
    s0 = jax.random.normal(ks[5], (b, h, n, n))
    y, sn = wkv6_chunked_ref(r, k, v, w, u, s0, chunk=16)
    yr, snr = ref.rwkv6_scan_ref(r.swapaxes(1, 2), k.swapaxes(1, 2),
                                 v.swapaxes(1, 2), w.swapaxes(1, 2), u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr.swapaxes(1, 2)),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sn), np.asarray(snr), atol=2e-4,
                               rtol=1e-3)


# --------------------------------------------------------------------------- #
# paged-KV block copy (the prefix cache's copy-on-write primitive)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("axis", [0, 2])
def test_copy_blocks_copies_listed_rows_only(axis):
    """copy_blocks must replicate exactly the src rows onto the dst rows
    along the given axis — other rows untouched, sources unmodified, and
    (0, 0) padding pairs must be no-ops."""
    shape = [5, 3, 6, 2]
    x = np.arange(np.prod(shape), dtype=np.float32).reshape(shape)
    src = jnp.asarray([2, 4, 0], jnp.int32)     # last pair: (0, 0) pad
    dst = jnp.asarray([1, 3, 0], jnp.int32)
    got = np.asarray(ops.copy_blocks(jnp.asarray(x), src, dst, axis=axis))
    want = x.copy()
    mv = np.moveaxis(want, axis, 0)
    mv[1] = np.moveaxis(x, axis, 0)[2]
    mv[3] = np.moveaxis(x, axis, 0)[4]
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------- #
# int8 KV block quantization (ADR-009 compressed disagg handoff)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 8, 2, 16), (3, 6, 4), (5, 12)])
def test_quantize_kv_blocks_matches_ref(shape, dtype):
    """Device quantize must match the loop-form oracle bit-for-bit on the
    int8 payload (scales/dequant to 1 ulp), and the round trip must stay
    within half a quantization step of the original per (block, head)."""
    blocks = _rand(KEY, shape, dtype) * 3.0
    q, scales = ops.quantize_kv_blocks(blocks)
    qr, sr = ref.quantize_kv_blocks_ref(blocks)
    assert q.dtype == jnp.int8 and scales.dtype == jnp.float32
    # keepdims: scales broadcast against blocks, one per (block, head).
    assert scales.ndim == blocks.ndim and scales.shape[0] == shape[0]
    want_scale_shape = tuple(
        n if i == 0 or (len(shape) >= 3 and i == len(shape) - 2) else 1
        for i, n in enumerate(shape))
    assert scales.shape == want_scale_shape
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(sr),
                               rtol=1e-6, atol=0)
    deq = ops.dequantize_kv_blocks(q, scales, dtype=dtype)
    deqr = ref.dequantize_kv_blocks_ref(qr, sr, dtype=dtype)
    assert deq.dtype == dtype
    # scales may differ by 1 ulp between the jnp and numpy paths, so the
    # dequantized payload is allclose-tight rather than bit-exact.
    np.testing.assert_allclose(np.asarray(deq.astype(jnp.float32)),
                               np.asarray(deqr.astype(jnp.float32)),
                               rtol=1e-6, atol=1e-6)
    # |x - deq(q(x))| <= scale/2 elementwise (round-to-nearest bound).
    err = np.abs(np.asarray(blocks, np.float32)
                 - np.asarray(deq, np.float32))
    bound = np.broadcast_to(np.asarray(scales), shape) * 0.5 + 1e-6
    if dtype == jnp.bfloat16:      # input itself only has ~8 mantissa bits
        bound = bound + 0.02 * np.abs(np.asarray(blocks, np.float32))
    assert np.all(err <= bound)


def test_quantize_kv_blocks_range_and_zeros():
    """Payload must use the full symmetric int8 range and map all-zero
    blocks to exact zeros (the 1e-12 scale floor must not inject noise)."""
    blocks = jnp.stack([jnp.full((4, 2, 8), 0.0, jnp.float32),
                        jnp.full((4, 2, 8), 5.0, jnp.float32)])
    q, scales = ops.quantize_kv_blocks(blocks)
    assert int(jnp.max(jnp.abs(q))) == 127
    np.testing.assert_array_equal(np.asarray(q[0]), 0)
    deq = ops.dequantize_kv_blocks(q, scales, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(deq[0]), 0.0)
    np.testing.assert_allclose(np.asarray(deq[1]), 5.0, rtol=1e-5)
