"""Event-driven Client Handler subsystem: virtual clock, dispatcher overlap,
parallel makespan on the timeline, elastic autoscaling, and continuous
batching equivalence.  Everything here is deterministic — no real sleeps."""
import time

import numpy as np
import pytest

from repro.core import (ClonePool, Dispatcher, ExecutionController,
                        Parallelizer, Policy, RemoteableMethod, VirtualClock,
                        split_batch)
from repro.core.clones import BOOT_SECONDS, CloneState, resume_time
from repro.core.parallel import SYNC_SECONDS_PER_CLONE
from repro.core.scheduler import (AdmissionQueue, ServeRequest, SlotLedger,
                                  poisson_arrivals)


# --------------------------------------------------------------------------- #
# virtual clock
# --------------------------------------------------------------------------- #
def test_virtual_clock_fires_events_in_order():
    clk = VirtualClock()
    fired = []
    clk.schedule(2.0, lambda: fired.append("b"))
    clk.schedule(1.0, lambda: fired.append("a"))
    clk.schedule(3.0, lambda: fired.append("c"))
    clk.advance_to(2.5)
    assert fired == ["a", "b"]
    assert clk.now() == 2.5
    clk.sleep(1.0)
    assert fired == ["a", "b", "c"]


def test_virtual_clock_rejects_time_travel():
    clk = VirtualClock(start=5.0)
    with pytest.raises(ValueError):
        clk.advance_to(1.0)
    with pytest.raises(ValueError):
        clk.at(1.0)


def test_virtual_clock_cancel_and_run_next():
    clk = VirtualClock()
    fired = []
    ev = clk.schedule(1.0, lambda: fired.append("x"))
    clk.schedule(2.0, lambda: fired.append("y"))
    ev.cancel()
    assert clk.run_next()
    assert fired == ["y"] and clk.now() == 2.0
    assert not clk.run_next()


# --------------------------------------------------------------------------- #
# dispatcher: k submissions overlap on the timeline
# --------------------------------------------------------------------------- #
def _fixed_executor(seconds_by_call):
    calls = {"n": 0}

    def ex(clone, fn, args):
        dt = seconds_by_call[min(calls["n"], len(seconds_by_call) - 1)]
        calls["n"] += 1
        return fn(*args), dt

    return ex


def test_dispatcher_overlap_is_max_not_sum():
    clk = VirtualClock()
    pool = ClonePool(clock=clk)
    clones = pool.provision("main", 3, state=CloneState.RUNNING)
    disp = Dispatcher(pool, clk)
    ex = _fixed_executor([1.0, 2.0, 3.0])
    tasks = [disp.submit(c, lambda v=i: v, (), executor=ex)
             for i, c in enumerate(clones)]
    disp.wait(tasks)
    assert clk.now() == pytest.approx(3.0)       # max, not 6.0
    assert [t.value for t in tasks] == [0, 1, 2]
    assert all(t.done for t in tasks)


def test_dispatcher_requires_virtual_clock():
    pool = ClonePool(clock=lambda: 0.0)
    with pytest.raises(TypeError):
        Dispatcher(pool, pool.clock)


# --------------------------------------------------------------------------- #
# parallelizer on the virtual timeline
# --------------------------------------------------------------------------- #
def test_parallel_makespan_is_provision_plus_max_plus_sync():
    """Acceptance: k-clone makespan within 10% of provision + max + sync,
    with zero real sleeping on the simulated path."""
    pool = ClonePool()                          # VirtualClock by default
    pool.provision("main", 4)                   # paused secondaries
    par = Parallelizer(pool)
    shard_times = {0: 1.0, 1: 2.0, 2: 4.0, 3: 3.0}

    def venue_executor(clone, fn, shard):
        i = int(shard[0])
        return i, shard_times[i]

    wall0 = time.perf_counter()
    res = par.run(lambda i: i, [(i,) for i in range(4)],
                  venue_executor=venue_executor, merge=sum)
    wall = time.perf_counter() - wall0
    # primary is RUNNING, 3 paused clones resume simultaneously
    expected = resume_time(3) + 4.0 + SYNC_SECONDS_PER_CLONE * 3
    assert res.makespan_s == pytest.approx(expected, rel=0.10)
    assert res.makespan_s == pytest.approx(expected, rel=1e-6)
    assert max(res.shard_times) == pytest.approx(4.0)
    assert res.value == 0 + 1 + 2 + 3
    assert wall < 1.0                           # simulated, not slept


def test_straggler_detected_at_event_time():
    pool = ClonePool()
    pool.provision("main", 6)
    par = Parallelizer(pool, straggler_factor=2.0)
    seen = {"rescues": 0}

    def venue_executor(clone, fn, shard):
        i = int(shard[0])
        if i == 3 and seen["rescues"] == 0:
            seen["rescues"] += 1
            return i, 50.0                      # straggling first attempt
        return i, 1.0

    res = par.run(lambda i: i, [(i,) for i in range(4)],
                  venue_executor=venue_executor, merge=list)
    assert res.redispatches == 1
    # detection at 2 x median(=1.0) => rescue lands at ~2 + resume + 1
    assert max(res.shard_times) == pytest.approx(
        2.0 + resume_time(1) + 1.0, rel=1e-6)
    assert res.value == [0, 1, 2, 3]


# --------------------------------------------------------------------------- #
# clone pool accounting (satellite regressions)
# --------------------------------------------------------------------------- #
def test_boot_seconds_counted_per_clone():
    pool = ClonePool()
    pool.acquire("x4large", n=3)                # three cold boots
    assert pool.stats["boots"] == 3
    assert pool.stats["boot_seconds"] == pytest.approx(3 * BOOT_SECONDS)


def test_ensure_secondaries_and_pause_surplus():
    pool = ClonePool()
    pool.provision("main", 2)                   # paused
    fresh, costs = pool.ensure_secondaries("main", 3)
    assert len(fresh) == 3                      # 2 resumed + 1 booted
    # per-clone readiness: resumed clones don't wait for the boot
    assert costs == pytest.approx([resume_time(2), resume_time(2),
                                   BOOT_SECONDS])
    assert len(pool.running_secondaries("main")) == 3
    assert all(not c.busy for c in fresh)       # idle capacity, not acquired
    assert pool.pause_surplus(keep=1, type_name="main") == 2
    assert len(pool.running_secondaries("main")) == 1


def test_parallel_run_feeds_network_profiler():
    """Multi-clone runs must update bandwidth/RTT history like single-clone
    runs, or later offload predictions go stale."""
    ec = ExecutionController(policy=Policy.EXEC_TIME)
    ec.pool.provision("main", 4, state=CloneState.RUNNING)
    rm = RemoteableMethod(
        "par", lambda xs: xs.sum(), size_fn=lambda xs: xs.size,
        split_fn=lambda args, k: split_batch(args, k),
        merge_fn=lambda vs: sum(float(v) for v in vs))
    ec.execute(rm, np.ones((8, 16), np.float32), force="remote", n_clones=4)
    assert ec.network.perceived_bw.get(ec.network.active)
    assert ec.network.perceived_rtt.get(ec.network.active)


# --------------------------------------------------------------------------- #
# admission queue
# --------------------------------------------------------------------------- #
def test_admission_queue_sheds_beyond_depth():
    q = AdmissionQueue(max_depth=2)
    reqs = [ServeRequest(i, np.zeros(4, np.int32)) for i in range(5)]
    admitted = [q.offer(r, now=0.0) for r in reqs]
    assert admitted == [True, True, False, False, False]
    assert q.rejected == 3
    assert [r.rid for r in q.take(10)] == [0, 1]


def test_slot_ledger_fills_tightest_engine_first():
    q = AdmissionQueue()
    for i in range(4):
        q.offer(ServeRequest(i, np.zeros(4, np.int32)), now=0.0)
    led = SlotLedger()
    led.update("a", 3)
    led.update("b", 1)
    assert led.total_free == 4
    picks = led.assign(q)
    # tightest engine (b, 1 free) is refilled before the emptier one
    assert [(k, r.rid) for k, r in picks] == \
        [("b", 0), ("a", 1), ("a", 2), ("a", 3)]
    assert led.total_free == 0 and q.depth == 0


def test_slot_ledger_drop_and_zero_update():
    led = SlotLedger()
    led.update("a", 2)
    led.update("a", 0)          # engine filled up -> forgotten
    led.update("b", 1)
    led.drop("b")
    q = AdmissionQueue()
    q.offer(ServeRequest(0, np.zeros(4, np.int32)), now=0.0)
    assert led.assign(q) == [] and q.depth == 1


def test_kv_block_pool_alloc_grow_free():
    from repro.launch.serve import KVBlockPool
    kv = KVBlockPool(FakeBackend(), max_slots=2, block_size=4)
    assert kv.max_blk == 16                     # capacity 64 / bs 4
    slot, ids, cached, cow = kv.alloc_slot(6, max_new_tokens=6)
    assert len(ids) == 2 and 0 not in ids       # trash block never handed out
    assert cached == 0 and cow is None          # bare length: no matching
    assert kv.pos[slot] == 6 and kv.used_blocks() == 2
    assert all(kv.ref[i] == 1 for i in ids)     # private blocks: refcount 1
    kv.active[slot] = True
    kv.pos[slot] = 8                            # cursor hits block boundary
    kv.grow_for_write()                         # next write needs block 3
    assert kv.n_blocks_of[slot] == 3 and kv.used_blocks() == 3
    kv.free_slot(slot)
    assert kv.used_blocks() == 0 and kv.free_slots == 2
    assert not kv.ref.any()                     # every refcount back to zero
    assert not kv.tables.any()                  # table rows reset to trash


def test_kv_block_pool_prefix_sharing_refcounts_and_cow():
    """Two prompts sharing a full token block map it at refcount 2; the
    first divergent block is claimed fresh with a copy-on-write source;
    freeing a sharer decrements, and the block only recirculates (via the
    cached-free list) at refcount zero."""
    from repro.launch.serve import KVBlockPool
    kv = KVBlockPool(FakeBackend(), max_slots=3, block_size=4)
    pa = np.array([7, 7, 7, 7, 1, 2, 3, 4, 9], np.int32)   # 2 full blocks
    pb = np.array([7, 7, 7, 7, 1, 2, 3, 5, 9], np.int32)   # diverges in b1
    sa, ids_a, ca, cow_a = kv.alloc_slot(pa, 4)
    assert ca == 0 and cow_a is None and len(ids_a) == 3
    sb, ids_b, cb, cow_b = kv.alloc_slot(pb, 4)
    assert cb == 4 + 3                          # full block + CoW partial
    assert kv.tables[sb, 0] == kv.tables[sa, 0]  # block 0 shared
    assert kv.ref[kv.tables[sa, 0]] == 2
    assert cow_b is not None
    assert cow_b[0] == kv.tables[sa, 1]          # CoW source: a's block 1
    assert cow_b[1] == kv.tables[sb, 1] != kv.tables[sa, 1]  # fresh copy
    kv.free_slot(sa)
    assert kv.ref[kv.tables[sb, 0]] == 1         # b still holds the share
    kv.free_slot(sb)
    assert not kv.ref.any()
    # the indexed prompt blocks stay cached-free: a re-admission of the
    # same prompt resurrects both full blocks and only allocates the
    # partial tail block (position 8 — never indexed, not full)
    free_before = kv.available_blocks()
    sc, ids_c, cc, cow_c = kv.alloc_slot(pa, 4)
    assert cc == 8 and len(ids_c) == 1 and cow_c is None
    assert kv.ref[kv.tables[sc, 0]] == 1 and kv.ref[kv.tables[sc, 1]] == 1
    # 2 resurrected from cached-free + 1 fresh: all now referenced
    assert kv.available_blocks() == free_before - 3


def test_kv_block_pool_cancel_unindexes_unwritten_blocks():
    """A cancelled admission (join rollback) must remove the trie nodes
    it created: their device content was never written, so matching them
    later would serve garbage KV.  A normally-freed slot's nodes stay."""
    from repro.launch.serve import KVBlockPool
    kv = KVBlockPool(FakeBackend(), max_slots=2, block_size=4)
    pa = np.arange(9, dtype=np.int32)
    s, _, c, _ = kv.alloc_slot(pa, 4)
    assert c == 0
    kv.cancel_slot(s)                           # prefill never ran
    s2, _, c2, cow2 = kv.alloc_slot(pa, 4)
    assert c2 == 0 and cow2 is None             # no garbage match
    kv.free_slot(s2)                            # normal retire: nodes stay
    _, _, c3, _ = kv.alloc_slot(pa, 4)
    assert c3 == 8                              # both full blocks hit


def test_kv_block_pool_optimistic_admission_and_exhaustion():
    """Admission gates on *prompt* blocks only (growth preempts instead of
    reserving worst case); a pool with every block referenced raises
    PoolExhausted — the engine's preemption trigger — on direct misuse."""
    from repro.launch.serve import KVBlockPool, PoolExhausted
    kv = KVBlockPool(FakeBackend(), max_slots=2, block_size=4, num_blocks=2)
    assert kv.can_admit(4, 60)                  # 1 prompt block fits
    kv.alloc_slot(4, 60)                        # takes the single real block
    assert not kv.can_admit(4, 0)               # no block left for a prompt
    with pytest.raises(PoolExhausted, match="exhausted"):
        kv.alloc_slot(4)                        # direct misuse still raises


def test_tight_block_pool_preempts_instead_of_crashing():
    """An under-provisioned pool (fewer blocks than the aggregate demand)
    must complete every request by preempting victims when decode growth
    exhausts the free list — not crash mid-flight, and not shed work."""
    h = _make_handler(max_batch=4, max_secondaries=0,
                      num_blocks=5, block_size=4,   # 4 real blocks
                      executor=lambda c, f, a: (f(*a), 0.1))
    # prompt 4 + 9 new tokens = 13 -> 4 blocks each vs 4 in the pool
    reqs = [ServeRequest(i, np.zeros(4, np.int32), 9, arrival_t=0.0)
            for i in range(6)]
    rep = h.run(reqs)
    assert len(rep.completions) == 6
    assert sorted(c.rid for c in rep.completions) == list(range(6))
    assert all(len(c.tokens) == 9 for c in rep.completions)
    assert rep.preemptions > 0                  # the pool really squeezed


def test_tight_pool_mid_flight_joins_respect_allocations():
    """Regression: two late arrivals offered to the same in-flight engine
    in one round must be admission-checked against each other's block
    allocations (fits() re-runs after every on_assign), not both against
    stale pre-round pool state."""
    h = _make_handler(max_batch=3, max_secondaries=0,
                      num_blocks=9, block_size=4,   # 8 real blocks
                      executor=lambda c, f, a: (f(*a), 0.5))
    # each request needs 4 blocks (prompt 4 + 12 new = 16 tokens)
    reqs = [ServeRequest(0, np.zeros(4, np.int32), 12, arrival_t=0.0),
            ServeRequest(1, np.zeros(4, np.int32), 12, arrival_t=1.2),
            ServeRequest(2, np.zeros(4, np.int32), 12, arrival_t=1.2)]
    rep = h.run(reqs)                           # crashed before the fix
    assert len(rep.completions) == 3
    assert all(len(c.tokens) == 12 for c in rep.completions)


def test_poisson_arrivals_deterministic():
    a = poisson_arrivals(4.0, 10, seed=3)
    b = poisson_arrivals(4.0, 10, seed=3)
    assert [r.arrival_t for r in a] == [r.arrival_t for r in b]
    assert all(x.arrival_t < y.arrival_t for x, y in zip(a, a[1:]))


# --------------------------------------------------------------------------- #
# client handler: elasticity + continuous batching (fake backend => pure
# virtual-clock scheduling, no model in the loop)
# --------------------------------------------------------------------------- #
class FakeBackend:
    """Token i+1 follows token i; venue time injected via executor.

    Implements both the contiguous cohort protocol (prefill/decode/
    cache_take) and the paged slot protocol (init_paged_pool/paged_fns),
    so handler tests exercise the real KVBlockPool/SlotLedger machinery
    with no model in the loop.
    """

    capacity = 64
    params = None

    def prefill(self, params, toks):
        b = int(toks.shape[0])
        return np.zeros(b, np.int32), {"state": np.zeros((b, 1), np.int32)}

    def decode(self, params, cache, tok, pos):
        return np.asarray(tok)[:, 0] + 1, cache

    def cache_take(self, cache, keep):
        return {"state": cache["state"][np.asarray(keep, np.int32)]}

    # --- paged slot protocol -------------------------------------------
    def init_paged_pool(self, max_slots, num_blocks, block_size):
        return {}

    def paged_fns(self, block_size, window=1, donate=False):
        def prefill_into(params, toks, pool, blk_ids, slots):
            return np.zeros(int(toks.shape[0]), np.int32), pool

        def decode_slots(params, pool, tok, pos, tables):
            return np.asarray(tok)[:, 0] + 1, pool

        def decode_window(params, pool, tok, pos, steps_left, tables):
            # mirrors model.decode_loop: live rows count up, dead rows
            # freeze their token
            cur = np.asarray(tok)[:, 0].astype(np.int32)
            sl = np.asarray(steps_left)
            out = np.zeros((cur.size, window), np.int32)
            for t in range(window):
                cur = np.where(t < sl, cur + 1, cur)
                out[:, t] = cur
            return out, pool

        return prefill_into, decode_slots, decode_window

    def prefill_window_fn(self, block_size, num_steps, donate=False):
        # suffix prefill (prefix hit / restore): first token matches the
        # full-prefill convention (always 0), KV content is not modeled
        def prefill_window(params, pool, toks, pos0, n_tok, tables):
            return np.zeros(int(np.asarray(toks).shape[0]), np.int32), pool

        return prefill_window

    def copy_fn(self, donate=False):
        return lambda pool, src, dst: pool

    def migrate_fn(self):
        # cross-pool KV copy: content is not modeled, the host-side
        # token carry (out[-1]) is what keeps decode deterministic
        return lambda dst, src, sids, dids, sslots, dslots: dst


def _make_handler(**kw):
    from repro.launch.serve import ClientHandler
    ex = kw.pop("executor", lambda clone, fn, args: (fn(*args), 0.05))
    return ClientHandler(FakeBackend(), executor=ex, prompt_pad=4, **kw)


def test_autoscaler_grows_and_ttl_pauses_under_burst():
    h = _make_handler(max_batch=1, max_secondaries=4, use_primary=False)
    reqs = [ServeRequest(i, np.zeros(4, np.int32), max_new_tokens=4,
                         arrival_t=0.001 * i) for i in range(12)]
    report = h.run(reqs, drain_idle_s=40.0)     # > PAUSE_IDLE_TTL
    assert len(report.completions) == 12
    assert report.peak_secondaries >= 2         # burst grew the pool
    assert report.pool_stats["resumes"] >= 2    # paused pool resumed, not
    assert report.pool_stats["boots"] == 0      # booted (pre-provisioned)
    # after the idle drain every secondary is paused again
    assert len(h.pool.running_secondaries()) == 0
    assert report.pool_stats["pauses"] >= 2
    # elasticity visible in the samples: grew then shrank
    counts = [n for _, n in report.clone_samples]
    assert max(counts) >= 2 and counts[-1] == 0


def test_handler_overlaps_cohorts_across_clones():
    """2 cohorts on 2 clones must overlap: makespan ~ max, not sum."""
    h = _make_handler(max_batch=1, max_secondaries=2, use_primary=False,
                      executor=lambda c, f, a: (f(*a), 1.0))
    reqs = [ServeRequest(i, np.zeros(4, np.int32), max_new_tokens=3,
                         arrival_t=0.0) for i in range(2)]
    report = h.run(reqs)
    # each request: prefill + 3 steps = 4 units of 1.0s (+resume +net);
    # serial would be >= 8s, overlapped is ~4s
    assert report.makespan_s < 6.0
    assert report.p50_latency_s < 6.0


def test_handler_requests_leave_at_step_granularity():
    h = _make_handler(max_batch=2, max_secondaries=1)
    reqs = [ServeRequest(0, np.zeros(4, np.int32), max_new_tokens=2),
            ServeRequest(1, np.zeros(4, np.int32), max_new_tokens=5)]
    report = h.run(reqs)
    by_rid = {c.rid: c for c in report.completions}
    assert by_rid[0].tokens == [0, 1]           # left after 2 tokens
    assert by_rid[1].tokens == [0, 1, 2, 3, 4]  # kept decoding alone
    assert by_rid[0].done_t < by_rid[1].done_t


def test_handler_adopts_supplied_pool_clock():
    """A supplied pool must share the handler's timeline, or TTL reaping
    would run on a clock frozen at 0 and never pause the secondaries."""
    from repro.launch.serve import ClientHandler
    clk = VirtualClock()
    pool = ClonePool(clock=clk)
    h = ClientHandler(FakeBackend(), pool=pool, max_secondaries=2,
                      prompt_pad=4,
                      executor=lambda c, f, a: (f(*a), 0.05))
    assert h.clock is clk
    reqs = [ServeRequest(i, np.zeros(4, np.int32), max_new_tokens=2,
                         arrival_t=0.0) for i in range(4)]
    h.run(reqs, drain_idle_s=40.0)
    assert clk.now() > 40.0                     # pool timeline advanced
    assert len(pool.running_secondaries()) == 0  # TTL pause actually fired
    with pytest.raises(TypeError):
        ClientHandler(FakeBackend(), pool=ClonePool(clock=lambda: 0.0))


def test_late_arrival_joins_in_flight_engine_next_step():
    """Paged mode: a request arriving while the only clone is mid-decode is
    admitted into a free slot at the next step boundary — it never waits
    for the cohort to drain, so its TTFT beats step-boundary fusion."""
    def trace():
        return [ServeRequest(0, np.zeros(4, np.int32), max_new_tokens=8,
                             arrival_t=0.0),
                ServeRequest(1, np.zeros(4, np.int32), max_new_tokens=3,
                             arrival_t=1.2)]     # mid-decode of rid 0

    def run(kv):
        h = _make_handler(max_batch=2, max_secondaries=0, kv=kv,
                          executor=lambda c, f, a: (f(*a), 0.5))
        return h.run(trace()), h

    rep_p, h_p = run("paged")
    rep_c, _ = run("contiguous")
    bp = {c.rid: c for c in rep_p.completions}
    bc = {c.rid: c for c in rep_c.completions}
    assert bp[1].ttft_s < bc[1].ttft_s          # joined mid-flight
    assert bp[1].tokens == bc[1].tokens == [0, 1, 2]
    assert bp[0].tokens == bc[0].tokens
    # one engine served both: the join reused the in-flight clone
    assert rep_p.pool_stats["resumes"] == 0
    assert rep_p.kv_mode == "paged" and rep_c.kv_mode == "contiguous"


def test_paged_slots_retire_independently_and_blocks_recycle():
    h = _make_handler(max_batch=3, max_secondaries=0,
                      executor=lambda c, f, a: (f(*a), 0.1))
    reqs = [ServeRequest(i, np.zeros(4, np.int32), max_new_tokens=n,
                         arrival_t=0.0) for i, n in enumerate((2, 5, 9))]
    rep = h.run(reqs)
    by = {c.rid: c for c in rep.completions}
    assert [len(by[i].tokens) for i in range(3)] == [2, 5, 9]
    assert by[0].done_t < by[1].done_t < by[2].done_t
    assert 0.0 < rep.kv_util <= 1.0


def test_paged_join_reuses_freed_slot():
    """More requests than slots: late arrivals take slots freed by earlier
    retirements on the same in-flight engine (blocks recycle)."""
    def run(kv):
        h = _make_handler(max_batch=2, max_secondaries=0, kv=kv,
                          executor=lambda c, f, a: (f(*a), 0.5))
        return h.run([
            ServeRequest(0, np.zeros(4, np.int32), 2, arrival_t=0.0),
            ServeRequest(1, np.zeros(4, np.int32), 6, arrival_t=0.0),
            ServeRequest(2, np.zeros(4, np.int32), 2, arrival_t=1.6)])

    rep, rep_c = run("paged"), run("contiguous")
    assert len(rep.completions) == 3
    by = {c.rid: c for c in rep.completions}
    by_c = {c.rid: c for c in rep_c.completions}
    assert by[2].tokens == [0, 1]
    # rid 2 took the slot rid 0 freed on the in-flight engine; under
    # step-boundary fusion it must wait for the whole cohort to drain
    assert by[2].ttft_s < by_c[2].ttft_s
    assert by[2].done_t <= by[1].done_t < by_c[2].done_t


def test_decode_window_token_identical_and_fewer_dispatches():
    """A multi-token decode window must emit exactly the per-token path's
    tokens while issuing ~1/T the decode dispatches — under a fixed
    per-dispatch venue cost that shows up directly as makespan."""
    def run(window):
        calls = {"n": 0}

        def ex(clone, fn, args):
            calls["n"] += 1
            return fn(*args), 0.5               # fixed cost per dispatch
        h = _make_handler(max_batch=2, max_secondaries=0, executor=ex,
                          decode_window=window)
        reqs = [ServeRequest(0, np.zeros(4, np.int32), max_new_tokens=8),
                ServeRequest(1, np.zeros(4, np.int32), max_new_tokens=5)]
        return h.run(reqs), calls["n"]

    rep1, n1 = run(1)
    rep4, n4 = run(4)
    by1 = {c.rid: c.tokens for c in rep1.completions}
    by4 = {c.rid: c.tokens for c in rep4.completions}
    assert by4 == by1                           # token-identical
    assert n4 < n1 / 2                          # window amortizes dispatch
    assert rep4.makespan_s < rep1.makespan_s


def test_decode_window_mid_window_completion_keeps_budgets():
    """Rows hitting their budget mid-window stop at exactly
    ``max_new_tokens`` tokens (the scan parks their writes, the host fold
    truncates at the submitted per-slot count)."""
    h = _make_handler(max_batch=3, max_secondaries=0, decode_window=4,
                      executor=lambda c, f, a: (f(*a), 0.1))
    reqs = [ServeRequest(i, np.zeros(4, np.int32), max_new_tokens=n,
                         arrival_t=0.0) for i, n in enumerate((1, 6, 10))]
    rep = h.run(reqs)
    by = {c.rid: c.tokens for c in rep.completions}
    assert [len(by[i]) for i in range(3)] == [1, 6, 10]
    # FakeBackend counts up from the prefill token: budgets sliced exactly
    assert by[2] == list(range(10))


def test_donate_kv_requires_single_run_executor():
    with pytest.raises(ValueError):
        from repro.launch.serve import ClientHandler
        ClientHandler(FakeBackend(), donate_kv=True)


def test_decode_window_rejected_on_contiguous_kv():
    from repro.launch.serve import ClientHandler
    with pytest.raises(ValueError):
        ClientHandler(FakeBackend(), kv="contiguous", decode_window=4,
                      executor=lambda c, f, a: (f(*a), 0.05))


def test_join_prefill_pads_to_power_of_two_buckets():
    """3 simultaneous joins prefill as one bucket-of-4 batched call; the
    prefill sees a padded row whose slot id is out of range."""
    seen = []

    class Probe(FakeBackend):
        def paged_fns(self, block_size, window=1, donate=False):
            pf, ds, dw = FakeBackend.paged_fns(self, block_size, window,
                                               donate)

            def prefill_into(params, toks, pool, blk_ids, slots):
                seen.append((int(toks.shape[0]), np.asarray(slots).copy()))
                return pf(params, toks, pool, blk_ids, slots)

            return prefill_into, ds, dw

    from repro.launch.serve import ClientHandler
    h = ClientHandler(Probe(), prompt_pad=4, max_batch=4, max_secondaries=0,
                      executor=lambda c, f, a: (f(*a), 0.05))
    reqs = [ServeRequest(i, np.zeros(4, np.int32), max_new_tokens=2,
                         arrival_t=0.0) for i in range(3)]
    rep = h.run(reqs)
    assert len(rep.completions) == 3
    j, slots = seen[0]
    assert j == 4                               # 3 joins -> bucket of 4
    assert slots[-1] == 4                       # pad row: out-of-range slot
    assert sorted(slots[:3]) == [0, 1, 2]


def test_handler_admission_control_sheds_load():
    h = _make_handler(max_batch=1, queue_depth=4, max_secondaries=1,
                      use_primary=False)
    reqs = [ServeRequest(i, np.zeros(4, np.int32), max_new_tokens=2,
                         arrival_t=0.0) for i in range(10)]
    report = h.run(reqs)
    assert report.rejected > 0
    assert report.accepted + report.rejected == 10
    assert len(report.completions) == report.accepted


# --------------------------------------------------------------------------- #
# heterogeneous fleet: placement engine, fleet autoscaler, escalation,
# chips-aware energy, TTL power-off (ADR-004)
# --------------------------------------------------------------------------- #
def test_placement_engine_cost_vs_urgency():
    """$-policy places bulk on the cheapest adequate tier; urgent demand
    ranks by provisioning latency, so a warm premium clone beats a paused
    cheap one beats a cold boot."""
    import pytest as _pytest
    from repro.core import ClonePool, Policy
    from repro.core.clones import BOOT_SECONDS, CloneState, resume_time
    from repro.core.scheduler import PlacementEngine
    pool = ClonePool(clock=lambda: 0.0)
    pool.provision("x2large", 1, state=CloneState.RUNNING)   # warm premium
    pool.provision("basic", 1)                               # paused cheap
    pe = PlacementEngine(pool, fleet=["basic", "main", "x2large"],
                         policy=Policy.NONE)
    assert pe.choose_type("basic") == "basic"                # cheapest $
    assert pe.choose_type("basic", urgent=True) == "x2large"  # fastest
    assert pe.choose_type("main") == "main"                  # floor holds
    preds = {t: pe.provision_pred(t)
             for t in ("basic", "main", "x2large")}
    assert preds["x2large"].time_s == 0.0
    assert preds["basic"].time_s == _pytest.approx(resume_time(1))
    assert preds["main"].time_s == BOOT_SECONDS
    assert preds["basic"].cost_usd < preds["main"].cost_usd \
        < preds["x2large"].cost_usd
    assert preds["basic"].energy_j < preds["x2large"].energy_j


def test_placement_required_type_escalates_and_degrades_at_top():
    """ISSUE 5 satellite: the KV floor walks ``ClonePool.escalate_type``
    (skipping non-fleet tiers); at the ladder's top (escalate_type ->
    None) the caller degrades gracefully to the biggest fleet tier —
    never an exception."""
    from repro.core import ClonePool
    from repro.core.scheduler import PlacementEngine
    pe = PlacementEngine(ClonePool(clock=lambda: 0.0),
                         fleet=["basic", "main"])
    real = {"basic": 3, "main": 7}
    assert pe.required_type("basic", 2, real.__getitem__) == "basic"
    assert pe.required_type("basic", 5, real.__getitem__) == "main"
    assert pe.required_type("basic", 99, real.__getitem__) == "main"


def test_choose_type_hints_spec_draft_affinity_and_breaker_degrade():
    """ISSUE 10 satellite: ``spec_draft`` picks the cheapest adequate
    tier regardless of policy; ``prefix_affinity`` ranks by cached-prefix
    depth through the full tier ladder while the depth's tier still has a
    serveable RUNNING clone, and degrades to the plain policy ranking
    when that clone's breaker trips — chasing an open-breaker clone's
    blocks would re-prefill on a cold pool anyway."""
    from repro.core import ClonePool, Policy
    from repro.core.clones import CloneState
    from repro.core.scheduler import PlacementEngine
    pool = ClonePool(clock=lambda: 0.0)
    pool.provision("basic", 1, state=CloneState.RUNNING)
    lg = pool.provision("large", 1, state=CloneState.RUNNING)[0]
    pe = PlacementEngine(pool, fleet=["basic", "main", "large", "x2large"],
                         policy=Policy.NONE)
    # spec_draft: cheapest adequate by $-rate; the required floor holds
    assert pe.choose_type("basic", hint="spec_draft") == "basic"
    assert pe.choose_type("main", hint="spec_draft") == "main"
    # prefix_affinity: the deepest live match beats the $-policy pick
    aff = {"large": 32, "basic": 8}
    assert pe.choose_type("basic", hint="prefix_affinity",
                          affinity=aff) == "large"
    # ...through the ladder: a floor above the deepest tier drops it from
    # the candidate set, and the deepest *eligible* live match wins
    assert pe.choose_type("main", hint="prefix_affinity",
                          affinity={"basic": 32, "large": 8}) == "large"
    # a depth only counts while its tier has a RUNNING serveable clone:
    # "x2large" has none, so its depth is dead weight and $-ranking rules
    assert pe.choose_type("basic", hint="prefix_affinity",
                          affinity={"x2large": 64}) == "basic"
    # zero affinity degrades to the plain policy ranking
    assert pe.choose_type("basic", hint="prefix_affinity",
                          affinity={}) == "basic"
    # breaker-open degrade: large's only clone trips, its cached depth
    # must stop counting, and the hint falls back to the $-ranking
    while lg.breaker.state == "closed":
        lg.breaker.record_failure(now=0.0)
    assert not lg.serveable
    assert pe.choose_type("basic", hint="prefix_affinity",
                          affinity=aff) == "basic"


def test_fleet_autoscaler_provisions_per_type_under_budget():
    """Demand buckets land on their placed tiers (resume cheap, boot the
    escalated tier) and the global secondary budget caps the total."""
    from repro.core import ClonePool, Policy
    from repro.core.scheduler import FleetAutoscaler, PlacementEngine
    pool = ClonePool(clock=lambda: 0.0)
    pool.provision("basic", 2)                               # paused
    pe = PlacementEngine(pool, fleet=["basic", "main", "large"],
                         policy=Policy.NONE)
    fa = FleetAutoscaler(pool, pe, base_type="basic", max_secondaries=4)
    targets = fa.step(0.0, [("basic", False, 2), ("large", False, 1)], {})
    assert targets["basic"] == 2 and targets["large"] == 1
    assert len(pool.running_secondaries("basic")) == 2
    assert len(pool.running_secondaries("large")) == 1
    assert pool.stats["resumes"] == 2 and pool.stats["boots"] == 1
    # budget: 10 more bulk units cannot exceed the global cap
    targets = fa.step(1.0, [("basic", False, 10)], {"large": 1})
    assert targets["basic"] + targets.get("large", 0) <= 4
    assert len(pool.running_secondaries()) <= 4


def test_fleet_autoscaler_tier_shift_pauses_stale_type_first():
    """Regression: when demand shifts tiers under a tight cap, the
    surplus pause must hit the *stale* (zero-target) tier — an untyped
    sweep paused the freshly booted target tier and livelocked the
    shift until the idle TTL reaped the stale clones."""
    from repro.core import ClonePool, Policy
    from repro.core.scheduler import FleetAutoscaler, PlacementEngine
    pool = ClonePool(clock=lambda: 0.0)
    pe = PlacementEngine(pool, fleet=["basic", "large"], policy=Policy.NONE)
    fa = FleetAutoscaler(pool, pe, base_type="basic", max_secondaries=2)
    fa.step(0.0, [("basic", False, 2)], {})
    assert len(pool.running_secondaries("basic")) == 2
    fa.step(1.0, [("large", False, 2)], {})
    assert len(pool.running_secondaries("large")) == 2   # target met NOW
    assert len(pool.running_secondaries("basic")) == 0   # stale tier paused


def test_min_secondaries_floor_survives_other_tier_demand():
    """Regression: the base tier's warm floor is reserved before any
    other tier's demand can consume the budget."""
    from repro.core import ClonePool, Policy
    from repro.core.scheduler import FleetAutoscaler, PlacementEngine
    pool = ClonePool(clock=lambda: 0.0)
    pe = PlacementEngine(pool, fleet=["basic", "large"], policy=Policy.NONE)
    fa = FleetAutoscaler(pool, pe, base_type="basic", min_secondaries=2,
                         max_secondaries=4)
    targets = fa.step(0.0, [("large", False, 4)], {})
    assert targets["basic"] == 2          # floor reserved first
    assert targets["large"] == 2          # remaining budget only


def test_free_primary_beats_booting_secondary():
    """Regression: a ready clone (the always-on primary) must never lose
    to one still paying its 32 s boot — readiness dominates tier rank in
    clone selection."""
    h = _make_handler(clone_type="basic", max_batch=1, max_secondaries=1,
                      use_primary=True, provision_paused=False,
                      executor=lambda c, f, a: (f(*a), 0.2))
    rep = h.run([ServeRequest(0, np.zeros(4, np.int32), 3, arrival_t=0.0)])
    assert rep.fleet_mix == {"main": 1}   # served on the idle primary
    assert rep.completions[0].ttft_s < 1.0   # not the secondary's boot


def test_fleet_handler_escalates_kv_hungry_requests():
    """A request whose prompt+window KV demand exceeds the base tier's
    block pool is escalated up the ladder and completes there; bulk stays
    on the cheap tier; the report carries the fleet economics."""
    h = _make_handler(clone_type="basic", fleet=["basic", "main"],
                      max_batch=2, max_secondaries=3, use_primary=False,
                      block_size=8, num_blocks=4,
                      executor=lambda c, f, a: (f(*a), 0.2))
    # rid 0 needs ceil(min(4+40, 64)/8) = 6 blocks > basic's 3 real
    reqs = [ServeRequest(0, np.zeros(4, np.int32), 40, arrival_t=0.0),
            ServeRequest(1, np.zeros(4, np.int32), 4, arrival_t=0.0),
            ServeRequest(2, np.zeros(4, np.int32), 4, arrival_t=0.0)]
    rep = h.run(reqs)
    by = {c.rid: c for c in rep.completions}
    assert sorted(by) == [0, 1, 2]
    assert len(by[0].tokens) == 40
    assert rep.escalations == 1
    assert rep.fleet_mix.get("main", 0) >= 1      # the escalated request
    assert rep.fleet_mix.get("basic", 0) >= 1     # the bulk
    assert rep.cost_usd > 0.0
    assert set(rep.energy_j_by_type) == {"basic", "main"}
    assert rep.clone_seconds_by_type["main"] > 0.0


def test_urgent_priority_lands_on_warm_premium_tier():
    """A high-priority request is placed latency-first: it takes the warm
    premium clone while the bulk behind it waits for the cheap tier's
    resume — and never the other way around."""
    from repro.core.clones import CloneState
    h = _make_handler(clone_type="basic", fleet=["basic", "x2large"],
                      max_batch=1, max_secondaries=2, use_primary=False,
                      executor=lambda c, f, a: (f(*a), 0.2))
    h.pool.provision("x2large", 1, state=CloneState.RUNNING)  # hot spare
    reqs = [ServeRequest(0, np.zeros(4, np.int32), 3, arrival_t=0.0,
                         priority=2, tenant="premium"),
            ServeRequest(1, np.zeros(4, np.int32), 3, arrival_t=0.0,
                         tenant="bulk")]
    rep = h.run(reqs)
    by = {c.rid: c for c in rep.completions}
    assert by[0].venue == "x2large"               # urgent took the spare
    assert by[1].venue == "basic"                 # bulk stayed cheap
    assert rep.fleet_mix == {"x2large": 1, "basic": 1}
    assert by[0].ttft_s < by[1].ttft_s            # no resume on its path
    # demand was tracked per tenant/priority class
    assert ("basic", True, "premium") in h.demand_by_class
    assert ("basic", False, "bulk") in h.demand_by_class


def test_primary_serves_homogeneous_non_main_clone_type():
    """Regression: a homogeneous handler pinned at a non-'main' type with
    no secondaries must still serve on the always-on primary (whose type
    is 'main') — the placement band must not band the standing primary
    out, in either direction of the rank ladder."""
    for ctype in ("basic", "x8large"):
        h = _make_handler(clone_type=ctype, max_batch=2, max_secondaries=0,
                          use_primary=True, provision_paused=False)
        rep = h.run([ServeRequest(0, np.zeros(4, np.int32), 3,
                                  arrival_t=0.0)])
        assert [c.tokens for c in rep.completions] == [[0, 1, 2]]
        assert rep.fleet_mix == {"main": 1}       # served on the primary


def test_contiguous_fleet_respects_placement_band():
    """The contiguous cohort path must seed with the request its clone
    was banded for — a band-blocked FIFO head must neither ride a
    premium clone nor displace the urgent request behind it."""
    from repro.core.clones import CloneState
    h = _make_handler(clone_type="basic", fleet=["basic", "x2large"],
                      kv="contiguous", max_batch=2, max_secondaries=1,
                      use_primary=False,
                      executor=lambda c, f, a: (f(*a), 0.2))
    h.pool.provision("x2large", 1, state=CloneState.RUNNING)
    reqs = [ServeRequest(0, np.zeros(4, np.int32), 3, arrival_t=0.0,
                         tenant="bulk"),
            ServeRequest(1, np.zeros(4, np.int32), 3, arrival_t=0.0,
                         priority=2, tenant="premium")]
    rep = h.run(reqs)
    by = {c.rid: c for c in rep.completions}
    assert by[1].venue == "x2large"               # urgent took the spare
    assert by[0].venue == "basic"                 # bulk waited for cheap


def test_busy_energy_is_chips_aware_x8large_vs_basic():
    """ISSUE 5 satellite: energy bills through TpuEnergyModel with the
    venue's chip count — an x8large step costs exactly
    (8*chip + host)/(1*chip + host) times a basic step, not the same."""
    from repro.core.energy import TpuCoeffs

    def run(ctype):
        h = _make_handler(clone_type=ctype, max_batch=1, max_secondaries=1,
                          use_primary=False,
                          executor=lambda c, f, a: (f(*a), 0.5))
        rep = h.run([ServeRequest(0, np.zeros(4, np.int32), 4,
                                  arrival_t=0.0)])
        return rep

    rep8, rep1 = run("x8large"), run("basic")
    c = TpuCoeffs()
    expect = (8 * c.chip_peak_w + c.host_w) / (1 * c.chip_peak_w + c.host_w)
    assert rep8.busy_energy_j / rep1.busy_energy_j == pytest.approx(expect)
    assert set(rep8.energy_j_by_type) == {"x8large"}
    assert rep8.energy_j_by_type["x8large"] == pytest.approx(
        rep8.busy_energy_j)


def test_drain_powers_off_long_idle_secondaries():
    """ISSUE 5 satellite: the drain loop steps the idle TTLs, so paused
    secondaries idle past OFF_IDLE_TTL actually power off and the report
    surfaces ``power_offs``."""
    from repro.core.clones import OFF_IDLE_TTL, PAUSE_IDLE_TTL, CloneState
    h = _make_handler(max_batch=1, max_secondaries=2, use_primary=False)
    reqs = [ServeRequest(i, np.zeros(4, np.int32), 2, arrival_t=0.0)
            for i in range(4)]
    rep = h.run(reqs, drain_idle_s=PAUSE_IDLE_TTL + OFF_IDLE_TTL + 40.0)
    assert rep.power_offs >= 1
    assert rep.power_offs == rep.pool_stats["offs"]
    assert all(c.state is CloneState.POWERED_OFF
               for c in h.pool.clones if not c.is_primary)


# --------------------------------------------------------------------------- #
# chunked prefill + unified mixed dispatch (ADR-005)
# --------------------------------------------------------------------------- #
def test_pow2_bucket():
    """ISSUE 6 satellite: the one pow2 padding helper every bucketed
    dispatch size goes through (join batches, CoW pair lists, suffix
    windows, chunk counts)."""
    from repro.launch.serve import pow2_bucket
    assert [pow2_bucket(n) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 16]
    assert pow2_bucket(1023) == 1024
    assert pow2_bucket(1024) == 1024
    for bad in (0, -3):
        with pytest.raises(ValueError):
            pow2_bucket(bad)


def test_chunked_prefill_handler_validation():
    """prefill_chunk/mixed_dispatch argument contract: chunking needs a
    backend that supports it (FakeBackend does not -> legacy default),
    mixed dispatch needs chunking."""
    from repro.launch.serve import ClientHandler
    h = _make_handler(max_batch=2)
    assert h.prefill_chunk == 0 and not h.mixed_dispatch
    with pytest.raises(ValueError):
        _make_handler(prefill_chunk=-1)
    with pytest.raises(ValueError):
        _make_handler(prefill_chunk=4)          # FakeBackend: unsupported
    with pytest.raises(ValueError):
        _make_handler(prefill_chunk=0, mixed_dispatch=True)
    with pytest.raises(ValueError):
        ClientHandler(FakeBackend(), kv="contiguous", prompt_pad=4,
                      prefill_chunk=4,
                      executor=lambda c, f, a: (f(*a), 0.05))


def test_mid_flight_join_routes_through_mixed_dispatch():
    """A prefix-hit join landing while the cohort decodes must ride the
    ONE fused mixed dispatch — never a separate suffix-prefill dispatch
    ahead of the decode window."""
    calls = {"mixed": 0, "sfx": 0}

    class ChunkProbe(FakeBackend):
        supports_chunked = True

        def prefill_window_fn(self, block_size, num_steps, donate=False,
                              chunk=0):
            def prefill_window(params, pool, toks, pos0, n_tok, tables):
                calls["sfx"] += 1
                return np.zeros(int(np.asarray(toks).shape[0]),
                                np.int32), pool

            return prefill_window

        def mixed_fn(self, block_size, chunk, num_steps, donate=False):
            def mixed(params, pool, tok, pos, steps_left, tables, stoks,
                      spos, sn, stabs):
                calls["mixed"] += 1
                cur = np.asarray(tok)[:, 0].astype(np.int32)
                sl = np.asarray(steps_left)
                out = np.zeros((cur.size, num_steps), np.int32)
                for t in range(num_steps):
                    cur = np.where(t < sl, cur + 1, cur)
                    out[:, t] = cur
                firsts = np.zeros(int(np.asarray(stoks).shape[0]),
                                  np.int32)
                return out, firsts, pool

            return mixed

    from repro.launch.serve import ClientHandler
    h = ClientHandler(ChunkProbe(), prompt_pad=8, max_batch=4,
                      max_secondaries=0, block_size=4, decode_window=2,
                      prefill_chunk=2, mixed_dispatch=True,
                      executor=lambda c, f, a: (f(*a), 0.05))
    assert h.prefill_chunk == 2 and h.mixed_dispatch
    # rid 0/1 at t=0 form the cohort (distinct prompts — no intra-cohort
    # prefix hit, so both fresh-prefill); rid 2 shares rid 0's first
    # (full) prompt block and lands mid-decode as a prefix-hit suffix
    # join whose divergence sits exactly on the block boundary (no CoW)
    joiner = np.concatenate([np.zeros(4, np.int32), np.ones(4, np.int32)])
    reqs = [ServeRequest(0, np.zeros(8, np.int32), max_new_tokens=6,
                         arrival_t=0.0),
            ServeRequest(1, np.full(8, 2, np.int32), max_new_tokens=6,
                         arrival_t=0.0),
            ServeRequest(2, joiner, max_new_tokens=6, arrival_t=0.06)]
    rep = h.run(reqs)
    assert len(rep.completions) == 3
    assert calls["mixed"] >= 1                  # join fused into the window
    assert calls["sfx"] == 0                    # no serial prefill dispatch


def test_chunked_and_mixed_dispatch_token_identical_end_to_end():
    """Real model, one shared-prefix trace, three serving configs —
    stepwise, chunked (split dispatch), chunked+mixed — must produce
    identical tokens for every request (the ADR-005 bitwise-parity
    claim, end to end through admission/join/fold)."""
    from repro.configs import get_config, reduced_config
    from repro.launch.serve import ClientHandler, LMBackend
    cfg = reduced_config(get_config("smollm-360m"))
    backend = LMBackend(cfg, capacity=32)

    def trace():
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
        reqs = []
        for i in range(8):
            tail = rng.integers(0, cfg.vocab_size, 4, dtype=np.int32)
            tail[0] = i                        # diverge at block boundary
            reqs.append(ServeRequest(i, np.concatenate([prefix, tail]), 4,
                                     arrival_t=0.08 * i))
        return reqs

    outs = []
    for chunk, mixed in ((0, False), (4, False), (4, True)):
        h = ClientHandler(backend, max_batch=4, prompt_pad=12,
                          block_size=4, max_secondaries=0,
                          decode_window=4, prefill_chunk=chunk,
                          mixed_dispatch=mixed,
                          executor=lambda c, f, a: (f(*a), 0.05))
        rep = h.run(trace())
        assert len(rep.completions) == 8
        outs.append({c.rid: list(map(int, c.tokens))
                     for c in rep.completions})
    assert outs[0] == outs[1] == outs[2]


_CHUNK_LM = []


def _chunk_lm_backend():
    """Shared reduced-model backend for the chunked-serving preemption
    checks (built once; also re-used by test_property.py)."""
    if not _CHUNK_LM:
        from repro.configs import get_config, reduced_config
        from repro.launch.serve import LMBackend
        cfg = reduced_config(get_config("smollm-360m"))
        _CHUNK_LM.append(LMBackend(cfg, capacity=32))
    return _CHUNK_LM[0]


def _run_tight_chunk_trace(seed, chunk, mixed):
    """Serve a seeded shared-prefix trace on a deliberately tight pool
    (preemption + restore pressure) and return the observables that must
    be invariant to prefill chunking: per-request tokens plus the
    refcount-governed pool economics counters."""
    from repro.launch.serve import ClientHandler
    backend = _chunk_lm_backend()
    vocab = backend.cfg.vocab_size
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, 8, dtype=np.int32)
    reqs = []
    for i in range(8):
        tail = rng.integers(0, vocab, 4, dtype=np.int32)
        tail[0] = i                            # diverge at block boundary
        reqs.append(ServeRequest(i, np.concatenate([prefix, tail]), 10,
                                 arrival_t=float(rng.uniform(0.0, 0.4))))
    h = ClientHandler(backend, max_batch=4, prompt_pad=12, block_size=4,
                      num_blocks=9, max_secondaries=0, decode_window=4,
                      prefill_chunk=chunk, mixed_dispatch=mixed,
                      executor=lambda c, f, a: (f(*a), 0.05))
    rep = h.run(reqs)
    return {"tokens": {c.rid: tuple(map(int, c.tokens))
                       for c in rep.completions},
            "served": len(rep.completions),
            "preemptions": rep.preemptions,
            "restored_tokens": rep.restored_tokens,
            "prefix_hits": h.prefix_hit_tokens}


def test_chunked_serving_preemption_restore_token_identical():
    """Mid-stream preemptions under pool pressure: stepwise and
    chunked+mixed serving of the same trace must emit identical tokens
    and identical preemption/restore/prefix-hit economics.  The
    host-side KVBlockPool refcount bookkeeping is shared between the two
    paths, so any divergence here is a chunk-kernel or dispatch-fold
    bug, not an accounting one."""
    for seed in (0, 1):
        a = _run_tight_chunk_trace(seed, 0, False)
        b = _run_tight_chunk_trace(seed, 4, True)
        assert a == b
        assert a["served"] == 8
        assert a["preemptions"] > 0 and a["restored_tokens"] > 0


# --------------------------------------------------------------------------- #
# cross-tier speculative decoding (ADR-008)
# --------------------------------------------------------------------------- #
class SpecFakeBackend(FakeBackend):
    """FakeBackend + the speculative protocol.

    The 'draft' proposes exactly the target's counting continuation
    (tok+1 .. tok+k) and the 'verify' maps every window token v to v+1,
    so acceptance is 1.0 unless the handler's corruption harness flips
    proposals — the host acceptance/EMA/fold machinery runs for real
    with no model in the loop.
    """

    supports_speculative = True
    draft_params = None

    class cfg:                      # corruption path reads vocab_size
        vocab_size = 1 << 30        # the +1 bump never wraps

    def init_draft_pool(self, max_slots, num_blocks, block_size):
        return {}

    def spec_draft_fn(self, block_size, catchup_steps, k_max):
        def draft(dparams, dpool, ctoks, cpos0, n_c, tok, pos, k_live,
                  tables):
            t = np.asarray(tok)[:, 0].astype(np.int32)
            k = np.asarray(k_live).astype(np.int32)
            step = np.arange(1, k_max + 1, dtype=np.int32)
            drafts = np.where(step[None, :] <= k[:, None],
                              t[:, None] + step[None, :], 0)
            return drafts.astype(np.int32), dpool

        return draft

    def spec_verify_fn(self, block_size):
        def verify(params, pool, toks, pos0, n_live, tables):
            return np.asarray(toks).astype(np.int32) + 1, pool

        return verify


def _spec_trace(n=6, new_tokens=9):
    return [ServeRequest(i, np.zeros(4, np.int32), new_tokens,
                         arrival_t=0.15 * i) for i in range(n)]


def _run_spec_handler(speculative, **kw):
    from repro.launch.serve import ClientHandler
    h = ClientHandler(SpecFakeBackend(), prompt_pad=4, max_batch=4,
                      max_secondaries=2, speculative=speculative,
                      executor=kw.pop("executor",
                                      lambda c, f, a: (f(*a), 0.05)),
                      **kw)
    rep = h.run(_spec_trace())
    return rep, h


def test_speculative_validation_errors():
    from repro.launch.serve import ClientHandler
    with pytest.raises(ValueError, match="draft model"):
        _make_handler(speculative=True)     # FakeBackend: no spec support
    kw = dict(prompt_pad=4, executor=lambda c, f, a: (f(*a), 0.05))
    with pytest.raises(ValueError, match="spec_k"):
        ClientHandler(SpecFakeBackend(), speculative=True, spec_k=0, **kw)
    with pytest.raises(ValueError, match="paged"):
        ClientHandler(SpecFakeBackend(), speculative=True,
                      kv="contiguous", **kw)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ClientHandler(SpecFakeBackend(), speculative=True,
                      mixed_dispatch=True, **kw)


def test_speculative_serving_token_identical_and_fewer_dispatches():
    """Oracle draft: the speculative run must emit bitwise the plain
    run's streams, accept every proposal, and spend strictly fewer
    target dispatches per token than stepwise decode."""
    plain, _ = _run_spec_handler(False, decode_window=1)
    spec, h = _run_spec_handler(True, spec_k=4)
    a = {c.rid: list(map(int, c.tokens)) for c in plain.completions}
    b = {c.rid: list(map(int, c.tokens)) for c in spec.completions}
    assert a == b and len(a) == 6
    assert spec.spec_rounds > 0 and spec.spec_tokens > 0
    assert spec.acceptance_rate == 1.0
    assert spec.spec_fallbacks == 0
    # dispatch economy: every spec round emits >= 1 token, most emit K+1
    assert spec.spec_tokens / spec.spec_rounds > 1.5
    assert h.spec_draft_cids                # a draft partner really paired


def test_speculative_corruption_partial_acceptance_token_identical():
    """Randomly corrupted proposals cut acceptance below 1.0 but can
    never change the emitted stream (rejected suffixes are garbage KV
    both pools overwrite on the next round)."""
    plain, _ = _run_spec_handler(False, decode_window=1)
    spec, _ = _run_spec_handler(True, spec_k=4, spec_corruption=0.4)
    a = {c.rid: list(map(int, c.tokens)) for c in plain.completions}
    b = {c.rid: list(map(int, c.tokens)) for c in spec.completions}
    assert a == b
    assert 0.0 < spec.acceptance_rate < 1.0


def test_speculative_acceptance_collapse_falls_back_to_plain_decode():
    """Near-total corruption collapses the acceptance EMA; the engine
    must stickily drop speculation (releasing the draft clone) and keep
    serving the exact same streams non-speculatively."""
    plain, _ = _run_spec_handler(False, decode_window=1)
    spec, h = _run_spec_handler(True, spec_k=4, spec_corruption=0.95)
    a = {c.rid: list(map(int, c.tokens)) for c in plain.completions}
    b = {c.rid: list(map(int, c.tokens)) for c in spec.completions}
    assert a == b
    assert spec.spec_fallbacks >= 1
    assert not any(e.spec_on for e in [])   # engines drained at run end


def test_speculative_no_draft_clone_degrades_nonspeculative():
    """A pool with no acquirable draft partner (max_clones=1: the
    primary is all there is) must serve the trace plainly, counted as a
    fallback — pairing failure is never a stall."""
    from repro.launch.serve import ClientHandler
    clk = VirtualClock()
    h = ClientHandler(SpecFakeBackend(), prompt_pad=4, max_batch=4,
                      pool=ClonePool(clock=clk, max_clones=1),
                      max_secondaries=0, speculative=True, spec_k=4,
                      executor=lambda c, f, a: (f(*a), 0.05))
    rep = h.run(_spec_trace())
    assert len(rep.completions) == 6
    assert rep.spec_rounds == 0
    assert rep.spec_fallbacks >= 1


def test_speculative_lm_serving_token_identical():
    """Real reduced model, oracle draft, mid-stream corruption: the
    speculative handler's streams must be bitwise the plain handler's
    (greedy decode is deterministic; ADR-008 losslessness end-to-end)."""
    import dataclasses

    from repro.configs import get_config, reduced_config
    from repro.launch.serve import ClientHandler, LMBackend
    cfg = reduced_config(get_config("smollm-360m"))
    backend = LMBackend(cfg, capacity=32, draft="oracle")
    vocab = cfg.vocab_size
    rng = np.random.default_rng(11)
    reqs = [ServeRequest(i, rng.integers(0, vocab, 6, dtype=np.int32), 8,
                         arrival_t=float(rng.uniform(0.0, 0.3)))
            for i in range(4)]

    def run(speculative, corruption=0.0):
        h = ClientHandler(backend, max_batch=4, prompt_pad=8,
                          block_size=4, max_secondaries=2,
                          decode_window=1, prefill_chunk=0,
                          speculative=speculative, spec_k=3,
                          spec_corruption=corruption,
                          executor=lambda c, f, a: (f(*a), 0.05))
        rep = h.run([dataclasses.replace(r) for r in reqs])
        return {c.rid: list(map(int, c.tokens)) for c in rep.completions}, \
            rep

    base, _ = run(False)
    for corr in (0.0, 0.35):
        toks, rep = run(True, corr)
        assert toks == base and len(toks) == 4
        assert rep.spec_rounds > 0
        assert rep.acceptance_rate > 0.0


# --------------------------------------------------------------------------- #
# disaggregated prefill/decode (ADR-009)
# --------------------------------------------------------------------------- #
class DisaggFakeBackend(FakeBackend):
    """FakeBackend + the chunked/disagg protocol.

    Prefill (local, chunked, or on the partner) always emits first token
    0 and decode counts up, so streams are deterministic regardless of
    which clone ran the prefill — KV content is not modeled, the
    host-side block bookkeeping and token carry are what's under test.
    """

    supports_chunked = True

    def prefill_window_fn(self, block_size, num_steps, donate=False,
                          chunk=0):
        def prefill_window(params, pool, toks, pos0, n_tok, tables):
            return np.zeros(int(np.asarray(toks).shape[0]), np.int32), pool

        return prefill_window

    def mixed_fn(self, block_size, chunk, steps, donate=False):
        def mixed(params, pool, tok, pos, steps_left, tables,
                  stoks, spos, sn, stabs):
            cur = np.asarray(tok)[:, 0].astype(np.int32)
            sl = np.asarray(steps_left)
            window = max(int(np.max(sl)) if sl.size else 1, 1)
            out = np.zeros((cur.size, window), np.int32)
            for t in range(window):
                cur = np.where(t < sl, cur + 1, cur)
                out[:, t] = cur
            firsts = np.zeros(int(np.asarray(stoks).shape[0]), np.int32)
            return out, firsts, pool

        return mixed

    def migrate_fn(self, compress=False):
        return lambda dst, src, sids, dids, sslots, dslots: dst


def test_disagg_validation_errors():
    from repro.launch.serve import ClientHandler
    ex = lambda c, f, a: (f(*a), 0.05)
    with pytest.raises(ValueError, match="kv='paged'"):
        ClientHandler(DisaggFakeBackend(), executor=ex, prompt_pad=4,
                      kv="contiguous", disagg=True)
    with pytest.raises(ValueError, match="chunked"):
        ClientHandler(FakeBackend(), executor=ex, prompt_pad=4,
                      disagg=True)     # no supports_chunked on the stub
    with pytest.raises(ValueError, match="disagg_link"):
        ClientHandler(DisaggFakeBackend(), executor=ex, prompt_pad=4,
                      disagg=True, disagg_link="carrier-pigeon")
    with pytest.raises(ValueError, match="routing"):
        ClientHandler(DisaggFakeBackend(), executor=ex, prompt_pad=4,
                      routing="bogus")
    with pytest.raises(ValueError, match="kv='paged'"):
        ClientHandler(DisaggFakeBackend(), executor=ex, prompt_pad=4,
                      kv="contiguous", routing="affinity")


def _disagg_trace():
    return [ServeRequest(i, np.full(8, i + 1, np.int32), 4,
                         arrival_t=0.05 * i) for i in range(6)]


def _run_disagg_fake(**kw):
    from repro.launch.serve import ClientHandler
    h = ClientHandler(DisaggFakeBackend(),
                      executor=kw.pop("executor",
                                      lambda c, f, a: (f(*a), 0.05)),
                      prompt_pad=8, max_batch=2, max_secondaries=4,
                      block_size=4, prefill_chunk=4, use_primary=False,
                      fleet=["basic", "large"], clone_type="basic", **kw)
    rep = h.run(_disagg_trace())
    return h, rep, {c.rid: list(map(int, c.tokens))
                    for c in rep.completions}


def test_disagg_handoffs_colocated_split_and_transfer_accounting():
    """Cold prompts over the disagg_min_prompt threshold hand off (one
    count + wire bytes/seconds each), a threshold above the effective
    prompt keeps every candidate co-located (planner says no, zero wire
    cost), and the int8 handoff ships <= half the uncompressed bytes —
    with every stream identical to the non-disagg baseline (the stub
    decodes the same count-up sequence wherever the prefill ran)."""
    _, rep0, t0 = _run_disagg_fake()
    assert len(t0) == 6 and rep0.disagg_handoffs == 0
    assert rep0.kv_transfer_bytes == 0 and rep0.kv_transfer_s == 0.0
    _, rep1, t1 = _run_disagg_fake(disagg=True, disagg_min_prompt=6,
                                   disagg_prefill_type="large")
    assert t1 == t0
    assert rep1.disagg_handoffs == 6       # every eff-8 prompt ships
    assert rep1.disagg_colocated == 0
    assert rep1.disagg_fallbacks == 0
    assert rep1.kv_transfer_bytes > 0 and rep1.kv_transfer_s > 0.0
    _, rep2, t2 = _run_disagg_fake(disagg=True, disagg_min_prompt=6,
                                   disagg_prefill_type="large",
                                   disagg_compress=True)
    assert t2 == t0
    assert rep2.disagg_handoffs == 6
    assert 0 < rep2.kv_transfer_bytes < 0.5 * rep1.kv_transfer_bytes
    # threshold above the padded prompt: the planner keeps every
    # candidate local — co-located counts, nothing on the wire
    _, rep3, t3 = _run_disagg_fake(disagg=True, disagg_min_prompt=100,
                                   disagg_prefill_type="large")
    assert t3 == t0
    assert rep3.disagg_handoffs == 0 and rep3.disagg_colocated == 6
    assert rep3.kv_transfer_bytes == 0


def test_disagg_partner_death_degrades_to_colocated_prefill():
    """Killing the shared prefill partner mid-trace must degrade every
    attached engine to co-located prefill (counted as fallbacks) with
    zero token loss — a partner death is never a stall and never
    corrupts a stream."""
    from repro.core.faults import CloneFault
    from repro.launch.serve import ClientHandler
    ex = lambda c, f, a: (f(*a), 0.05)

    def run(faults):
        # decode on the primary: the shared large partner is then the
        # only running secondary, so cid=None targets it at fire time
        h = ClientHandler(DisaggFakeBackend(), executor=ex, prompt_pad=4,
                          max_batch=8, max_secondaries=2, block_size=4,
                          prefill_chunk=4, fleet=["main", "large"],
                          disagg=True, disagg_min_prompt=1,
                          disagg_prefill_type="large", faults=faults)
        rep = h.run([ServeRequest(i, np.full(8, i + 1, np.int32), 4,
                                  arrival_t=0.3 * i) for i in range(4)])
        return rep, {c.rid: list(map(int, c.tokens))
                     for c in rep.completions}

    rep0, t0 = run(None)
    assert rep0.disagg_handoffs == 4 and rep0.disagg_fallbacks == 0
    rep1, t1 = run([CloneFault(at=0.35, kind="kill")])
    assert t1 == t0                        # count-up streams, no loss
    assert rep1.disagg_fallbacks >= 1
    assert rep1.disagg_handoffs < 4        # post-death prompts stay local
    assert rep1.faults_injected == 1


def test_disagg_lm_serving_token_identical():
    """Real reduced model: disaggregated prefill (partner clone + paged
    block migration) must be bitwise the co-located handler on the same
    trace when uncompressed, and the int8 handoff must complete every
    stream at <= half the wire bytes (ADR-009 end to end)."""
    from repro.launch.serve import ClientHandler
    backend = _chunk_lm_backend()
    vocab = backend.cfg.vocab_size
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, vocab, 8, dtype=np.int32)
    reqs = []
    for i in range(4):
        tail = rng.integers(0, vocab, 4, dtype=np.int32)
        tail[0] = i
        reqs.append(ServeRequest(i, np.concatenate([prefix, tail]), 6,
                                 arrival_t=0.05 * i))

    def run(**kw):
        h = ClientHandler(backend, max_batch=4, prompt_pad=12,
                          block_size=4, max_secondaries=4,
                          decode_window=4,
                          executor=lambda c, f, a: (f(*a), 0.05), **kw)
        rep = h.run([dataclasses.replace(r) for r in reqs])
        return rep, {c.rid: list(map(int, c.tokens))
                     for c in rep.completions}

    import dataclasses
    _, t0 = run()
    rep1, t1 = run(fleet=["basic", "large"], clone_type="basic",
                   disagg=True, disagg_min_prompt=1,
                   disagg_prefill_type="large")
    assert rep1.disagg_handoffs >= 1
    assert t1 == t0 and len(t1) == 4
    rep2, t2 = run(fleet=["basic", "large"], clone_type="basic",
                   disagg=True, disagg_min_prompt=1,
                   disagg_prefill_type="large", disagg_compress=True)
    assert len(t2) == 4
    assert all(len(v) == 6 for v in t2.values())
    assert 0 < rep2.kv_transfer_bytes < 0.5 * rep1.kv_transfer_bytes


def _assert_blocks_conserved(kv):
    """Post-drain allocator conservation for one KVBlockPool: no live
    refs, no leaked block, no double-free (free / cached-free partition
    the physical blocks; the trash block stays clean)."""
    assert not np.asarray(kv.ref).any(), "live refcount after drain"
    free = set(kv._free_blocks)
    cached = set(kv._cached_free)
    assert len(kv._free_blocks) == len(free), "double-free: dup free list"
    assert not free & cached
    assert free | cached == set(range(1, kv.num_blocks)), "leaked block"
    assert 0 not in free and 0 not in cached


def run_disagg_affinity_trace(seed, *, routing="ledger", disagg=False,
                              compress=False):
    """Serve a seeded shared-prefix trace (2 families x 3 requests) on
    the reduced model and return its observables; asserts KV-block
    conservation over every per-clone pool and partner scratch pool on
    the way out.  The ADR-009 property harness: ``test_property.py``
    sweeps (seed, routing, disagg, compress) through this."""
    from repro.launch.serve import ClientHandler
    backend = _chunk_lm_backend()
    vocab = backend.cfg.vocab_size
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, 8, dtype=np.int32)
                for _ in range(2)]
    reqs = []
    for i in range(6):
        tail = rng.integers(0, vocab, 4, dtype=np.int32)
        tail[0] = i                        # diverge at block boundary
        reqs.append(ServeRequest(
            i, np.concatenate([prefixes[i % 2], tail]), 6,
            arrival_t=float(rng.uniform(0.0, 0.6))))
    kw = {}
    if disagg:
        kw = dict(fleet=["basic", "large"], disagg=True,
                  disagg_min_prompt=1, disagg_prefill_type="large",
                  disagg_compress=compress)
    h = ClientHandler(backend, max_batch=2, prompt_pad=12, block_size=4,
                      max_secondaries=4, decode_window=4,
                      clone_type="basic", use_primary=False,
                      routing=routing,
                      executor=lambda c, f, a: (f(*a), 0.05), **kw)
    rep = h.run(reqs)
    for kv in list(h._kv_pools.values()) + list(h._prefill_pools.values()):
        _assert_blocks_conserved(kv)
    return {"tokens": {c.rid: tuple(map(int, c.tokens))
                       for c in rep.completions},
            "served": len(rep.completions),
            "offered": 6,
            "handoffs": rep.disagg_handoffs,
            "fallbacks": rep.disagg_fallbacks,
            "xfer_bytes": rep.kv_transfer_bytes}


def test_disagg_affinity_routing_conserves_blocks_and_tokens():
    """Deterministic twin of the ADR-009 property (test_property.py):
    any routing mode x disagg handoff serves the whole shared-prefix
    trace with zero block leak and — compression off — streams bitwise
    identical to the co-located ledger-routed baseline."""
    base = run_disagg_affinity_trace(3)
    assert base["served"] == 6 and base["handoffs"] == 0
    for routing in ("affinity", "random"):
        out = run_disagg_affinity_trace(3, routing=routing, disagg=True)
        assert out["tokens"] == base["tokens"]
        assert out["handoffs"] >= 1 and out["fallbacks"] == 0
    comp = run_disagg_affinity_trace(3, routing="affinity", disagg=True,
                                     compress=True)
    assert comp["served"] == 6
    assert 0 < comp["xfer_bytes"] < out["xfer_bytes"]
