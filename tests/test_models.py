"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import draft_config, get_config, list_archs, \
    reduced_config
from repro.models import model
from repro.models.context import RunContext

KEY = jax.random.PRNGKey(3)


def make_batch(cfg, b, s, key=KEY):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "audio":
        return {"frames": jax.random.normal(k1, (b, s, cfg.d_model)),
                "targets": jax.random.randint(k2, (b, s), 0, cfg.vocab_size),
                "loss_mask": jnp.ones((b, s), jnp.float32)}
    if cfg.frontend == "vision":
        p = cfg.n_patches
        return {"patches": jax.random.normal(k1, (b, p, cfg.d_model)),
                "tokens": jax.random.randint(k2, (b, s - p), 0,
                                             cfg.vocab_size),
                "targets": jax.random.randint(k3, (b, s - p), 0,
                                              cfg.vocab_size)}
    return {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
            "targets": jax.random.randint(k2, (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + finite."""
    from repro.launch import steps as S
    from repro.optim.adamw import OptConfig

    cfg = reduced_config(get_config(arch))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    loss, metrics = model.forward(cfg, params, batch, ctx, "train")
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    step = jax.jit(S.build_train_step(cfg, OptConfig(), ctx))
    state = {"params": params, "opt": __import__(
        "repro.optim.adamw", fromlist=["init"]).init(params)}
    new_state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(new_state["opt"]["step"]) == 1
    # params actually changed
    diff = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if not get_config(a).encoder_only])
def test_prefill_decode_consistency(arch):
    """Incremental decode must match fresh prefill logits (serving oracle)."""
    cfg = reduced_config(get_config(arch))
    ctx = RunContext(moe_capacity_factor=(cfg.n_experts / cfg.top_k
                                          if cfg.is_moe else 1.25))
    params = model.init(cfg, KEY)
    b, s, s0 = 2, 12, 4
    if cfg.frontend == "vision":
        pytest.skip("vlm decode covered via text path (prefix in cache)")
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)

    ref_logits = []
    for t in range(s0, s + 1):
        lg, _ = model.forward(cfg, params, {"tokens": toks[:, :t]}, ctx,
                              "prefill")
        ref_logits.append(np.asarray(lg, np.float32))

    lg, cache = model.forward(cfg, params, {"tokens": toks[:, :s0]}, ctx,
                              "prefill", cache_capacity=s)
    outs = [np.asarray(lg, np.float32)]
    for t in range(s0, s):
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                      jnp.int32(t), ctx)
        outs.append(np.asarray(lg, np.float32))
    for i, (a, b_) in enumerate(zip(ref_logits, outs)):
        np.testing.assert_allclose(a, b_, atol=5e-4, rtol=5e-3,
                                   err_msg=f"{arch} step {i}")


def test_sliding_window_ring_decode_past_window():
    """Decode beyond the window: ring cache must equal fresh prefill."""
    import dataclasses
    cfg = reduced_config(get_config("mixtral-8x7b"))
    cfg = dataclasses.replace(cfg, window=4)
    ctx = RunContext(moe_capacity_factor=cfg.n_experts / cfg.top_k)
    params = model.init(cfg, KEY)
    b, s = 1, 12
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    # prefill exactly the window, decode 8 more (wraps the ring twice)
    lg, cache = model.forward(cfg, params, {"tokens": toks[:, :4]}, ctx,
                              "prefill")
    for t in range(4, s):
        want, _ = model.forward(cfg, params, {"tokens": toks[:, :t]}, ctx,
                                "prefill")
        np.testing.assert_allclose(np.asarray(lg), np.asarray(want),
                                   atol=5e-4, rtol=5e-3, err_msg=f"t={t}")
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                      jnp.int32(t), ctx)


def test_paligemma_prefix_attention_bidirectional():
    """Patch positions must see later patches (prefix-LM), text stays causal."""
    cfg = reduced_config(get_config("paligemma-3b"))
    params = model.init(cfg, KEY)
    ctx = RunContext()
    b, s = 1, 8
    batch = make_batch(cfg, b, s)
    # perturb the LAST patch; prefix-LM => loss must change (first patch
    # attends to it), while under causal-only it could not affect position 0
    lg1, _ = model.forward(cfg, params, batch, ctx, "prefill")
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"].at[:, -1].add(10.0)
    lg2, _ = model.forward(cfg, params, batch2, ctx, "prefill")
    assert float(jnp.max(jnp.abs(lg1 - lg2))) > 0


def test_pallas_impl_matches_xla_impl():
    """Reduced model forward with impl=pallas (interpret) == impl=xla."""
    for arch in ("qwen2.5-3b", "rwkv6-7b", "recurrentgemma-2b"):
        cfg = reduced_config(get_config(arch))
        params = model.init(cfg, KEY)
        batch = make_batch(cfg, 2, 16)
        l1, _ = model.forward(cfg, params, batch,
                              RunContext(impl="xla"), "train")
        l2, _ = model.forward(cfg, params, batch,
                              RunContext(impl="pallas"), "train")
        np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3,
                                   err_msg=arch)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor E/k, no tokens are dropped (exact routing)."""
    cfg = reduced_config(get_config("olmoe-1b-7b"))
    params = model.init(cfg, KEY)
    batch = make_batch(cfg, 2, 16)
    full = RunContext(moe_capacity_factor=cfg.n_experts / cfg.top_k)
    tight = RunContext(moe_capacity_factor=0.25)
    l_full, _ = model.forward(cfg, params, batch, full, "train")
    l_tight, _ = model.forward(cfg, params, batch, tight, "train")
    assert np.isfinite(float(l_full)) and np.isfinite(float(l_tight))


def _paged_decode_state(cfg, ctx, params, prompt_lens, block_size, capacity):
    """Write each slot's random prompt into a paged pool via decode steps.

    Returns (cache, tables, tok (B,1), pos (B,)) — the state a serving
    engine would hold right before a decode window.
    """
    slots = len(prompt_lens)
    max_blk = capacity // block_size
    pool = model.init_paged_cache(cfg, slots, slots * max_blk + 1,
                                  block_size)
    rng = np.random.default_rng(11)
    tables = np.zeros((slots, max_blk), np.int32)
    nxt_blk = 1
    for i, ln in enumerate(prompt_lens):
        for j in range(-(-ln // block_size)):
            tables[i, j] = nxt_blk
            nxt_blk += 1
    prompts = [rng.integers(0, cfg.vocab_size, ln).astype(np.int32)
               for ln in prompt_lens]
    tok = np.zeros((slots, 1), np.int32)
    pos = np.zeros((slots,), np.int32)
    for t in range(max(prompt_lens)):
        cur = np.array([[p[t] if t < len(p) else 0] for p in prompts],
                       np.int32)
        live = np.array([t < len(p) for p in prompts])
        logits, pool = model.decode_step(
            cfg, params, pool, jnp.asarray(cur),
            jnp.asarray(np.where(live, pos, 0)), ctx,
            block_tables=jnp.asarray(np.where(live[:, None], tables, 0)),
            block_size=block_size)
        nx = np.asarray(jnp.argmax(logits, -1), np.int32)
        tok = np.where(live[:, None], nx[:, None], tok)
        pos = np.where(live, pos + 1, pos)
    return pool, tables, tok, pos


def _stepwise_decode(cfg, ctx, params, cache, tables, tok, pos, budgets,
                     block_size, capacity, num_steps):
    """The PR-2 per-token path: T decode_step dispatches with host masking
    between steps (dead rows -> trash block), mirroring the serving loop."""
    cur, p = tok[:, 0].copy(), pos.copy()
    out = np.zeros((len(budgets), num_steps), np.int32)
    for t in range(num_steps):
        live = t < budgets
        logits, cache = model.decode_step(
            cfg, params, cache, jnp.asarray(cur[:, None]),
            jnp.asarray(np.where(live, np.minimum(p, capacity - 1), 0)),
            ctx,
            block_tables=jnp.asarray(np.where(live[:, None], tables, 0)),
            block_size=block_size)
        nx = np.asarray(jnp.argmax(logits, -1), np.int32)
        cur = np.where(live, nx, cur)
        out[:, t] = cur
        p = np.where(live, np.minimum(p + 1, capacity), p)
    return out, cache


def test_decode_loop_matches_stepwise_decode():
    """decode_loop(T) — one on-device scan — must emit token-identical
    output to T host-driven decode_step calls, across ragged budgets
    (mid-window completions park in the trash block) and an inactive slot,
    and leave a bitwise-identical KV pool behind."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap, T = 4, 16, 4
    cache, tables, tok, pos = _paged_decode_state(
        cfg, ctx, params, prompt_lens=[3, 5, 1], block_size=bs, capacity=cap)
    budgets = np.array([T, 2, 0], np.int32)     # full / mid-window / empty

    want, cache_ref = _stepwise_decode(
        cfg, ctx, params, jax.tree.map(jnp.copy, cache), tables, tok, pos,
        budgets, bs, cap, T)
    got, cache_win = model.decode_loop(
        cfg, params, jax.tree.map(jnp.copy, cache), jnp.asarray(tok),
        jnp.asarray(pos), jnp.asarray(budgets), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=T,
        capacity=cap)
    np.testing.assert_array_equal(np.asarray(got), want)
    for a, b in zip(jax.tree.leaves(cache_ref), jax.tree.leaves(cache_win)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_loop_past_capacity_clamps_like_stepwise():
    """A window running past ``capacity`` pins its writes to the last cell
    exactly like the per-token path (the contiguous-path clamp rule)."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap, T = 4, 8, 6
    cache, tables, tok, pos = _paged_decode_state(
        cfg, ctx, params, prompt_lens=[6, 4], block_size=bs, capacity=cap)
    budgets = np.array([T, T], np.int32)        # slot 0 crosses capacity
    want, _ = _stepwise_decode(
        cfg, ctx, params, jax.tree.map(jnp.copy, cache), tables, tok, pos,
        budgets, bs, cap, T)
    got, _ = model.decode_loop(
        cfg, params, jax.tree.map(jnp.copy, cache), jnp.asarray(tok),
        jnp.asarray(pos), jnp.asarray(budgets), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=T,
        capacity=cap)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_prefill_loop_matches_stepwise_prompt_feed():
    """The suffix-prefill scan (one dispatch) must write bitwise the KV a
    host-driven per-token prompt feed writes, and return the same first
    generated token — including a per-row *offset* start (the prefix-hit
    path: only the uncached suffix is fed) and an inactive pad row."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap = 4, 16
    prompt_lens = [6, 9, 3]
    # reference: prompts written via per-token decode steps (host loop)
    cache_ref, tables, tok_ref, pos_ref = _paged_decode_state(
        cfg, ctx, params, prompt_lens, block_size=bs, capacity=cap)
    rng = np.random.default_rng(11)             # same stream -> same prompts
    prompts = [rng.integers(0, cfg.vocab_size, ln).astype(np.int32)
               for ln in prompt_lens]
    # scan path: same prompts, same tables, fresh pool, plus a pad row
    pool = model.init_paged_cache(cfg, 3, 3 * (cap // bs) + 1, bs)
    tmax = max(prompt_lens)
    toks = np.zeros((3, tmax), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    firsts, pool = model.prefill_loop(
        cfg, params, pool, jnp.asarray(toks),
        jnp.asarray(np.zeros(3, np.int32)),
        jnp.asarray(np.asarray(prompt_lens, np.int32)), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=tmax,
        capacity=cap)
    np.testing.assert_array_equal(np.asarray(firsts), tok_ref[:, 0])
    for a, b in zip(jax.tree.leaves(cache_ref), jax.tree.leaves(pool)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # offset restart: re-feed only the last 4 tokens of row 1's prompt
    # into the same pool (positions 5..8) — KV must stay bitwise stable
    # and the first token must reproduce (the restore-path invariant)
    sfx = np.zeros((3, 4), np.int32)
    sfx[1] = prompts[1][-4:]
    n_tok = np.array([0, 4, 0], np.int32)
    pos0 = np.array([0, prompt_lens[1] - 4, 0], np.int32)
    f2, pool2 = model.prefill_loop(
        cfg, params, pool, jnp.asarray(sfx), jnp.asarray(pos0),
        jnp.asarray(n_tok), ctx, block_tables=jnp.asarray(tables),
        block_size=bs, num_steps=4, capacity=cap)
    assert int(np.asarray(f2)[1]) == int(tok_ref[1, 0])
    for a, b in zip(jax.tree.leaves(cache_ref), jax.tree.leaves(pool2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _assert_live_blocks_equal(pool_a, pool_b):
    """Bitwise-compare every pool block except trash block 0.

    Dead rows park their writes in the trash block, whose final contents
    legitimately differ between execution orders — no reader ever gathers
    it for a live position, so it is excluded from identity claims.
    Cache leaves are (..., N, bs, Hkv, D) with leading layer-group dims,
    so the block axis is ndim-4.
    """
    for a, b in zip(jax.tree.leaves(pool_a), jax.tree.leaves(pool_b)):
        ax = a.ndim - 4
        am = np.moveaxis(np.asarray(a), ax, 0)
        bm = np.moveaxis(np.asarray(b), ax, 0)
        np.testing.assert_array_equal(am[1:], bm[1:])


def _staged_suffix_state(cfg, ctx, params, prefix_lens, n_tok, bs, cap,
                         seed=11):
    """Stage per-row prefixes into a paged pool; return the suffix batch.

    Returns (pool, tables, sfx_toks (B, tmax), pos0, n_tok) — the state
    right before a suffix prefill (prefix-hit join / restore): positions
    0..prefix_lens[i]-1 hold KV, the suffix tokens are not yet written.
    """
    slots = len(prefix_lens)
    max_blk = cap // bs
    pool = model.init_paged_cache(cfg, slots, slots * max_blk + 1, bs)
    rng = np.random.default_rng(seed)
    tables = np.zeros((slots, max_blk), np.int32)
    nxt = 1
    for i in range(slots):
        for j in range(max_blk):
            tables[i, j] = nxt
            nxt += 1
    pre_max = max(max(prefix_lens), 1)
    pre = np.zeros((slots, pre_max), np.int32)
    for i, ln in enumerate(prefix_lens):
        pre[i, :ln] = rng.integers(0, cfg.vocab_size, ln)
    _, pool = model.prefill_loop(
        cfg, params, pool, jnp.asarray(pre),
        jnp.asarray(np.zeros(slots, np.int32)),
        jnp.asarray(np.asarray(prefix_lens, np.int32)), ctx,
        block_tables=jnp.asarray(tables), block_size=bs,
        num_steps=pre_max, capacity=cap)
    tmax = max(n_tok)
    sfx = np.zeros((slots, tmax), np.int32)
    for i, n in enumerate(n_tok):
        sfx[i, :n] = rng.integers(0, cfg.vocab_size, n)
    return pool, tables, sfx, np.asarray(prefix_lens, np.int32), \
        np.asarray(n_tok, np.int32)


@pytest.mark.parametrize("chunk", [1, 2, 4, 8])
def test_prefill_chunks_matches_prefill_loop(chunk):
    """The chunked suffix scan (⌈T/chunk⌉ steps) must return the same
    first tokens as the stepwise scan (T steps) and leave every live pool
    block bitwise identical — across ragged suffixes, nonzero start
    cursors, and a dead (n_tok == 0) pad row."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap = 4, 32
    pool, tables, sfx, pos0, n_tok = _staged_suffix_state(
        cfg, ctx, params, prefix_lens=[0, 8, 5], n_tok=[7, 9, 0],
        bs=bs, cap=cap)
    tmax = sfx.shape[1]
    f_ref, pool_ref = model.prefill_loop(
        cfg, params, jax.tree.map(jnp.copy, pool), jnp.asarray(sfx),
        jnp.asarray(pos0), jnp.asarray(n_tok), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=tmax,
        capacity=cap)
    f_chk, pool_chk = model.prefill_chunks(
        cfg, params, jax.tree.map(jnp.copy, pool), jnp.asarray(sfx),
        jnp.asarray(pos0), jnp.asarray(n_tok), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, chunk=chunk,
        num_steps=-(-tmax // chunk), capacity=cap)
    live = n_tok > 0
    np.testing.assert_array_equal(np.asarray(f_chk)[live],
                                  np.asarray(f_ref)[live])
    _assert_live_blocks_equal(pool_ref, pool_chk)


_CHUNK_FIX = []


def _chunk_fixture():
    """Shared tiny model for the chunked-prefill identity checks (built
    once so seeded sweeps and the hypothesis property re-use params)."""
    if not _CHUNK_FIX:
        cfg = reduced_config(get_config("smollm-360m"))
        _CHUNK_FIX.append((cfg, RunContext(), model.init(cfg, KEY)))
    return _CHUNK_FIX[0]


def _check_chunked_vs_stepwise(prefix_lens, n_tok, chunk, seed=11):
    """Stage seeded prefixes, then assert prefill_chunks == prefill_loop
    (first tokens on live rows + every live pool block bitwise)."""
    cfg, ctx, params = _chunk_fixture()
    bs, cap = 4, 32
    n_tok = list(n_tok)
    if max(n_tok) == 0:
        n_tok[0] = 1                            # at least one live row
    pool, tables, sfx, pos0, nt = _staged_suffix_state(
        cfg, ctx, params, prefix_lens=list(prefix_lens), n_tok=n_tok,
        bs=bs, cap=cap, seed=seed)
    tmax = sfx.shape[1]
    f_ref, pool_ref = model.prefill_loop(
        cfg, params, jax.tree.map(jnp.copy, pool), jnp.asarray(sfx),
        jnp.asarray(pos0), jnp.asarray(nt), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=tmax,
        capacity=cap)
    f_chk, pool_chk = model.prefill_chunks(
        cfg, params, jax.tree.map(jnp.copy, pool), jnp.asarray(sfx),
        jnp.asarray(pos0), jnp.asarray(nt), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, chunk=chunk,
        num_steps=-(-tmax // chunk), capacity=cap)
    live = nt > 0
    np.testing.assert_array_equal(np.asarray(f_chk)[live],
                                  np.asarray(f_ref)[live])
    _assert_live_blocks_equal(pool_ref, pool_chk)


def test_prefill_chunks_random_lengths_token_identical():
    """Seeded random prefix/suffix lengths x chunk sizes — the
    deterministic twin of the hypothesis property in test_property.py
    (which is skipped where hypothesis is not installed)."""
    rng = np.random.default_rng(0)
    for chunk in (1, 2, 3, 4, 8):
        prefix_lens = rng.integers(0, 9, 3).tolist()
        n_tok = rng.integers(0, 9, 3).tolist()
        _check_chunked_vs_stepwise(prefix_lens, n_tok, chunk,
                                   seed=int(rng.integers(1 << 30)))


def test_prefill_chunks_zero_suffix_and_block_boundary():
    """Regression (ISSUE 6 satellite): zero-length suffix rows must leave
    their resident blocks untouched (writes park in the trash block), and
    rows whose pos0 + n_tokens lands exactly on a block boundary — or past
    capacity — must clamp bitwise like the stepwise scan."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap = 4, 16
    # row 0: 8+8 = 16 ends exactly at capacity (block boundary);
    # row 1: 12+6 = 18 overruns capacity -> capacity-1 clamp;
    # row 2: zero-length suffix on a staged 6-token prefix
    pool, tables, sfx, pos0, n_tok = _staged_suffix_state(
        cfg, ctx, params, prefix_lens=[8, 12, 6], n_tok=[8, 6, 0],
        bs=bs, cap=cap)
    tmax = sfx.shape[1]
    f_ref, pool_ref = model.prefill_loop(
        cfg, params, jax.tree.map(jnp.copy, pool), jnp.asarray(sfx),
        jnp.asarray(pos0), jnp.asarray(n_tok), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=tmax,
        capacity=cap)
    f_chk, pool_chk = model.prefill_chunks(
        cfg, params, jax.tree.map(jnp.copy, pool), jnp.asarray(sfx),
        jnp.asarray(pos0), jnp.asarray(n_tok), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, chunk=4,
        num_steps=2, capacity=cap)
    np.testing.assert_array_equal(np.asarray(f_chk)[:2],
                                  np.asarray(f_ref)[:2])
    _assert_live_blocks_equal(pool_ref, pool_chk)
    # the dead row's resident blocks are bitwise untouched by both paths:
    # its writes went to trash block 0, never to a live block
    row2 = tables[2][tables[2] > 0]
    for before, after in ((pool, pool_ref), (pool, pool_chk)):
        for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            ax = a.ndim - 4
            np.testing.assert_array_equal(
                np.take(np.asarray(a), row2, axis=ax),
                np.take(np.asarray(b), row2, axis=ax))


def test_mixed_loop_matches_split_prefill_then_decode():
    """mixed_loop — ONE scan fusing the decode window with joining rows'
    chunked suffix prefill — must emit bitwise what the split path emits
    (prefill_chunks, then decode_loop), across ragged decode budgets
    including an inactive slot, because the two tiles touch disjoint
    blocks."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap, W, C = 4, 16, 4, 2
    cache, tables, tok, pos = _paged_decode_state(
        cfg, ctx, params, prompt_lens=[3, 5, 1, 4, 5, 2], block_size=bs,
        capacity=cap)
    dec_tbl, sfx_tbl = tables[:4], tables[4:]
    budgets = np.array([W, 2, 0, W], np.int32)
    rng = np.random.default_rng(3)
    sfx = rng.integers(0, cfg.vocab_size, (2, 6), dtype=np.int32)
    spos = np.array([5, 2], np.int32)
    sn = np.array([6, 3], np.int32)             # ragged; tmax 6 -> 3 chunks
    n_chunks = -(-sfx.shape[1] // C)

    f_ref, pool1 = model.prefill_chunks(
        cfg, params, jax.tree.map(jnp.copy, cache), jnp.asarray(sfx),
        jnp.asarray(spos), jnp.asarray(sn), ctx,
        block_tables=jnp.asarray(sfx_tbl), block_size=bs, chunk=C,
        num_steps=n_chunks, capacity=cap)
    dec_ref, pool_ref = model.decode_loop(
        cfg, params, pool1, jnp.asarray(tok[:4]), jnp.asarray(pos[:4]),
        jnp.asarray(budgets), ctx, block_tables=jnp.asarray(dec_tbl),
        block_size=bs, num_steps=W, capacity=cap)

    dec_m, f_m, pool_m = model.mixed_loop(
        cfg, params, jax.tree.map(jnp.copy, cache), jnp.asarray(tok[:4]),
        jnp.asarray(pos[:4]), jnp.asarray(budgets), jnp.asarray(sfx),
        jnp.asarray(spos), jnp.asarray(sn), ctx,
        block_tables=jnp.asarray(dec_tbl),
        sfx_tables=jnp.asarray(sfx_tbl), block_size=bs, chunk=C,
        num_steps=max(W, n_chunks), capacity=cap)
    np.testing.assert_array_equal(np.asarray(f_m), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(dec_m)[:, :W],
                                  np.asarray(dec_ref))
    _assert_live_blocks_equal(pool_ref, pool_m)


def test_paged_cache_rejects_non_full_attention():
    """Regression (ISSUE 4 satellite): paged KV requires full attention —
    both guard sites must keep raising a clean NotImplementedError for a
    windowed/recurrent config instead of silently mis-gathering."""
    cfg = reduced_config(get_config("recurrentgemma-2b"))
    assert cfg.window is not None               # local-attention config
    with pytest.raises(NotImplementedError, match="full attention"):
        model.init_paged_cache(cfg, 2, 9, 4)    # models/model.py guard
    # models/blocks.py guard: a decode step handed block tables on a
    # windowed config must refuse at trace time, whatever the cache is
    params = model.init(cfg, KEY)
    cache = model.init_cache(cfg, 2, 8)
    with pytest.raises(NotImplementedError, match="full attention"):
        model.decode_step(cfg, params, cache,
                          jnp.zeros((2, 1), jnp.int32),
                          jnp.zeros((2,), jnp.int32), RunContext(),
                          block_tables=jnp.zeros((2, 2), jnp.int32),
                          block_size=4)


def test_cache_logical_axes_match_cache_structure():
    for arch in list_archs():
        cfg = reduced_config(get_config(arch))
        if cfg.encoder_only:
            continue
        cache = model.abstract_cache(cfg, 2, 8)
        axes = model.cache_logical_axes(cfg)
        ok = jax.tree.map(lambda c, a: len(c.shape) == len(a), cache, axes)
        assert all(jax.tree.leaves(ok)), arch


# --------------------------------------------------------------------------- #
# Speculative decoding: draft_loop / verify_window (ADR-008)
# --------------------------------------------------------------------------- #
def _spec_state(cfg, ctx, params, prompt_lens, bs, cap, seed=11):
    """Stage seeded prompts into a paged pool via one prefill scan.

    Returns (pool, tables, prompts, tok (B,), pos (B,)): the serving state
    right before decoding — ``tok[i]`` is row i's first generated (current,
    KV-unwritten) token at cursor ``pos[i] = len(prompts[i])``.
    """
    slots = len(prompt_lens)
    max_blk = cap // bs
    pool = model.init_paged_cache(cfg, slots, slots * max_blk + 1, bs)
    rng = np.random.default_rng(seed)
    tables = np.zeros((slots, max_blk), np.int32)
    nxt = 1
    for i in range(slots):
        for j in range(max_blk):
            tables[i, j] = nxt
            nxt += 1
    prompts = [rng.integers(0, cfg.vocab_size, ln).astype(np.int32)
               for ln in prompt_lens]
    pre = np.zeros((slots, max(prompt_lens)), np.int32)
    for i, p in enumerate(prompts):
        pre[i, :len(p)] = p
    first, pool = model.prefill_loop(
        cfg, params, pool, jnp.asarray(pre),
        jnp.asarray(np.zeros(slots, np.int32)),
        jnp.asarray(np.asarray(prompt_lens, np.int32)), ctx,
        block_tables=jnp.asarray(tables), block_size=bs,
        num_steps=pre.shape[1], capacity=cap)
    return pool, tables, prompts, np.asarray(first, np.int32), \
        np.asarray(prompt_lens, np.int32)


def _run_spec_rounds(cfg, ctx, params, dcfg, dparams, pool, dpool, tables,
                     hist, tok, pos, budgets, bs, cap, k_max, flip_p, rng):
    """Drive draft_loop + verify_window rounds until every budget drains.

    The draft is an oracle (or a real reduced model when dcfg/dparams
    differ) whose proposals are corrupted with per-token probability
    ``flip_p`` and whose window size is drawn per-row per-round — random
    K, mid-window rejections, and dead rows all fall out of the draw.
    Returns (out per-row token lists, cur, pos, pool).
    """
    slots = len(budgets)
    cur, p, left = tok.copy(), pos.copy(), np.asarray(budgets, np.int32)
    left = left.copy()
    dp = np.zeros((slots,), np.int32)           # draft pool cursor
    out = [[] for _ in range(slots)]
    guard = 0
    while (left > 0).any():
        guard += 1
        assert guard <= 4 * (int(left.max()) + 1), "spec loop diverged"
        live = left > 0
        room = np.maximum(cap - 1 - np.minimum(p, cap - 1), 0)
        k_cap = np.minimum(np.minimum(k_max, left - 1), room)
        k = np.where(live, rng.integers(0, np.maximum(k_cap, 0) + 1), 0)
        k = k.astype(np.int32)
        # --- draft side: catch-up (hist[dp:p]) + k greedy steps ---
        n_c = np.where(live, p - dp, 0).astype(np.int32)
        tc = max(int(n_c.max()), 1)
        ctoks = np.zeros((slots, tc), np.int32)
        for i in range(slots):
            if n_c[i]:
                ctoks[i, :n_c[i]] = hist[i][dp[i]:p[i]]
        drafts, dpool = model.draft_loop(
            dcfg, dparams, dpool, jnp.asarray(ctoks),
            jnp.asarray(np.where(live, dp, 0).astype(np.int32)),
            jnp.asarray(n_c), jnp.asarray(cur[:, None]),
            jnp.asarray(np.where(live, p, 0).astype(np.int32)),
            jnp.asarray(k), ctx, block_tables=jnp.asarray(tables),
            block_size=bs, catchup_steps=tc, num_steps=k_max, capacity=cap)
        drafts = np.asarray(drafts, np.int32)
        flips = rng.random((slots, k_max)) < flip_p
        drafts = np.where(flips, (drafts + 1) % cfg.vocab_size, drafts)
        dp = np.where(live, p + k, dp)
        # --- verify side: one chunked dispatch over k+1 window tokens ---
        x = np.concatenate([cur[:, None], drafts], axis=1)
        n_live = np.where(live, k + 1, 0).astype(np.int32)
        greedy, pool = model.verify_window(
            cfg, params, pool, jnp.asarray(x),
            jnp.asarray(np.where(live, np.minimum(p, cap - 1), 0)),
            jnp.asarray(n_live), ctx, block_tables=jnp.asarray(tables),
            block_size=bs, capacity=cap)
        greedy = np.asarray(greedy, np.int32)
        acc = model.spec_accept(greedy, drafts, np.where(live, k, 0))
        for i in range(slots):
            if live[i]:
                got = greedy[i, :acc[i] + 1].tolist()
                out[i].extend(got)
                hist[i].extend(got)
        emitted = np.where(live, acc + 1, 0).astype(np.int32)
        cur = np.where(live, greedy[np.arange(slots), acc], cur)
        p = np.where(live, np.minimum(p + emitted, cap), p)
        left = left - emitted
        dp = np.where(live, np.minimum(dp, p), dp)
    return out, cur, p, pool


def _check_spec_vs_stepwise(prompt_lens, budgets, k_max, flip_p, seed=11,
                            cap=32, real_draft=False):
    """Full speculative decode (oracle/real draft, random per-round K,
    corrupted proposals) must emit token-identical output to stepwise
    greedy decode — and leave committed KV a continuation can't tell
    apart (stale rejected-position KV is provably never read)."""
    cfg, ctx, params = _chunk_fixture()
    bs = 4
    budgets = np.asarray(budgets, np.int32)
    if budgets.max() == 0:
        budgets = budgets.copy()
        budgets[0] = 1                          # at least one live row
    pool, tables, prompts, tok, pos = _spec_state(
        cfg, ctx, params, list(prompt_lens), bs, cap, seed=seed)
    T = int(budgets.max())
    want, pool_ref = _stepwise_decode(
        cfg, ctx, params, jax.tree.map(jnp.copy, pool), tables,
        tok[:, None], pos, budgets, bs, cap, T)

    if real_draft:
        dcfg = draft_config(get_config("smollm-360m"))
        dparams = model.init(dcfg, jax.random.PRNGKey(7))
    else:
        dcfg, dparams = cfg, params             # oracle draft
    slots = len(prompt_lens)
    max_blk = cap // bs
    dpool = model.init_paged_cache(dcfg, slots, slots * max_blk + 1, bs)
    hist = [p.tolist() + [int(tok[i])] for i, p in enumerate(prompts)]
    rng = np.random.default_rng(seed + 1)
    out, cur, p, pool_spec = _run_spec_rounds(
        cfg, ctx, params, dcfg, dparams, jax.tree.map(jnp.copy, pool),
        dpool, tables, hist, tok, pos, budgets, bs, cap, k_max, flip_p, rng)

    for i in range(slots):
        np.testing.assert_array_equal(
            np.asarray(out[i], np.int32), want[i, :budgets[i]],
            err_msg=f"slot {i} speculative stream != stepwise greedy")
    # cursors and current tokens line up with the stepwise endpoint
    p_ref = np.minimum(pos + budgets, cap)
    np.testing.assert_array_equal(p, p_ref)
    # committed KV is intact: a plain stepwise continuation from the same
    # (token, cursor) state must match on both pools — this reads every
    # committed position and causally masks the stale rejected tail
    ext = np.minimum(np.maximum(cap - p, 0), 3).astype(np.int32)
    if ext.max() > 0:
        cont_ref, _ = _stepwise_decode(
            cfg, ctx, params, pool_ref, tables, cur[:, None], p, ext,
            bs, cap, int(ext.max()))
        cont_spec, _ = _stepwise_decode(
            cfg, ctx, params, pool_spec, tables, cur[:, None], p, ext,
            bs, cap, int(ext.max()))
        np.testing.assert_array_equal(cont_spec, cont_ref)


def test_verify_window_token_identical_sweep():
    """Deterministic twin of the hypothesis property (PR 6 pattern):
    seeded sweeps over draft quality — oracle-perfect (full accepts),
    always-wrong (every window rejects at position 0, degenerating to
    per-token decode), and mid-window rejections — plus a dead row and
    ragged budgets."""
    for flip_p, seed in [(0.0, 3), (1.0, 5), (0.35, 7), (0.5, 11)]:
        _check_spec_vs_stepwise(prompt_lens=[3, 5, 1], budgets=[6, 4, 0],
                                k_max=3, flip_p=flip_p, seed=seed)


def test_verify_window_capacity_clamp_matches_stepwise():
    """Windows shrink to k=0 at the capacity edge (no pinned-write
    collapse is ever allowed inside a verify window), matching the
    stepwise clamp bitwise."""
    _check_spec_vs_stepwise(prompt_lens=[6, 4], budgets=[8, 8], k_max=3,
                            flip_p=0.2, seed=13, cap=8)


def test_real_reduced_draft_model_is_still_lossless():
    """A genuinely different (randomly initialized, architecturally
    smaller) draft model mostly disagrees with the target — acceptance
    collapses — but the emitted stream must STILL be token-identical:
    verification makes draft quality a pure performance knob."""
    _check_spec_vs_stepwise(prompt_lens=[4, 2], budgets=[5, 3], k_max=3,
                            flip_p=0.0, seed=17, real_draft=True)
