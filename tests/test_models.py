"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.models import model
from repro.models.context import RunContext

KEY = jax.random.PRNGKey(3)


def make_batch(cfg, b, s, key=KEY):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "audio":
        return {"frames": jax.random.normal(k1, (b, s, cfg.d_model)),
                "targets": jax.random.randint(k2, (b, s), 0, cfg.vocab_size),
                "loss_mask": jnp.ones((b, s), jnp.float32)}
    if cfg.frontend == "vision":
        p = cfg.n_patches
        return {"patches": jax.random.normal(k1, (b, p, cfg.d_model)),
                "tokens": jax.random.randint(k2, (b, s - p), 0,
                                             cfg.vocab_size),
                "targets": jax.random.randint(k3, (b, s - p), 0,
                                              cfg.vocab_size)}
    return {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
            "targets": jax.random.randint(k2, (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + finite."""
    from repro.launch import steps as S
    from repro.optim.adamw import OptConfig

    cfg = reduced_config(get_config(arch))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    loss, metrics = model.forward(cfg, params, batch, ctx, "train")
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    step = jax.jit(S.build_train_step(cfg, OptConfig(), ctx))
    state = {"params": params, "opt": __import__(
        "repro.optim.adamw", fromlist=["init"]).init(params)}
    new_state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(new_state["opt"]["step"]) == 1
    # params actually changed
    diff = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if not get_config(a).encoder_only])
def test_prefill_decode_consistency(arch):
    """Incremental decode must match fresh prefill logits (serving oracle)."""
    cfg = reduced_config(get_config(arch))
    ctx = RunContext(moe_capacity_factor=(cfg.n_experts / cfg.top_k
                                          if cfg.is_moe else 1.25))
    params = model.init(cfg, KEY)
    b, s, s0 = 2, 12, 4
    if cfg.frontend == "vision":
        pytest.skip("vlm decode covered via text path (prefix in cache)")
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)

    ref_logits = []
    for t in range(s0, s + 1):
        lg, _ = model.forward(cfg, params, {"tokens": toks[:, :t]}, ctx,
                              "prefill")
        ref_logits.append(np.asarray(lg, np.float32))

    lg, cache = model.forward(cfg, params, {"tokens": toks[:, :s0]}, ctx,
                              "prefill", cache_capacity=s)
    outs = [np.asarray(lg, np.float32)]
    for t in range(s0, s):
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                      jnp.int32(t), ctx)
        outs.append(np.asarray(lg, np.float32))
    for i, (a, b_) in enumerate(zip(ref_logits, outs)):
        np.testing.assert_allclose(a, b_, atol=5e-4, rtol=5e-3,
                                   err_msg=f"{arch} step {i}")


def test_sliding_window_ring_decode_past_window():
    """Decode beyond the window: ring cache must equal fresh prefill."""
    import dataclasses
    cfg = reduced_config(get_config("mixtral-8x7b"))
    cfg = dataclasses.replace(cfg, window=4)
    ctx = RunContext(moe_capacity_factor=cfg.n_experts / cfg.top_k)
    params = model.init(cfg, KEY)
    b, s = 1, 12
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    # prefill exactly the window, decode 8 more (wraps the ring twice)
    lg, cache = model.forward(cfg, params, {"tokens": toks[:, :4]}, ctx,
                              "prefill")
    for t in range(4, s):
        want, _ = model.forward(cfg, params, {"tokens": toks[:, :t]}, ctx,
                                "prefill")
        np.testing.assert_allclose(np.asarray(lg), np.asarray(want),
                                   atol=5e-4, rtol=5e-3, err_msg=f"t={t}")
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                      jnp.int32(t), ctx)


def test_paligemma_prefix_attention_bidirectional():
    """Patch positions must see later patches (prefix-LM), text stays causal."""
    cfg = reduced_config(get_config("paligemma-3b"))
    params = model.init(cfg, KEY)
    ctx = RunContext()
    b, s = 1, 8
    batch = make_batch(cfg, b, s)
    # perturb the LAST patch; prefix-LM => loss must change (first patch
    # attends to it), while under causal-only it could not affect position 0
    lg1, _ = model.forward(cfg, params, batch, ctx, "prefill")
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"].at[:, -1].add(10.0)
    lg2, _ = model.forward(cfg, params, batch2, ctx, "prefill")
    assert float(jnp.max(jnp.abs(lg1 - lg2))) > 0


def test_pallas_impl_matches_xla_impl():
    """Reduced model forward with impl=pallas (interpret) == impl=xla."""
    for arch in ("qwen2.5-3b", "rwkv6-7b", "recurrentgemma-2b"):
        cfg = reduced_config(get_config(arch))
        params = model.init(cfg, KEY)
        batch = make_batch(cfg, 2, 16)
        l1, _ = model.forward(cfg, params, batch,
                              RunContext(impl="xla"), "train")
        l2, _ = model.forward(cfg, params, batch,
                              RunContext(impl="pallas"), "train")
        np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3,
                                   err_msg=arch)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor E/k, no tokens are dropped (exact routing)."""
    cfg = reduced_config(get_config("olmoe-1b-7b"))
    params = model.init(cfg, KEY)
    batch = make_batch(cfg, 2, 16)
    full = RunContext(moe_capacity_factor=cfg.n_experts / cfg.top_k)
    tight = RunContext(moe_capacity_factor=0.25)
    l_full, _ = model.forward(cfg, params, batch, full, "train")
    l_tight, _ = model.forward(cfg, params, batch, tight, "train")
    assert np.isfinite(float(l_full)) and np.isfinite(float(l_tight))


def _paged_decode_state(cfg, ctx, params, prompt_lens, block_size, capacity):
    """Write each slot's random prompt into a paged pool via decode steps.

    Returns (cache, tables, tok (B,1), pos (B,)) — the state a serving
    engine would hold right before a decode window.
    """
    slots = len(prompt_lens)
    max_blk = capacity // block_size
    pool = model.init_paged_cache(cfg, slots, slots * max_blk + 1,
                                  block_size)
    rng = np.random.default_rng(11)
    tables = np.zeros((slots, max_blk), np.int32)
    nxt_blk = 1
    for i, ln in enumerate(prompt_lens):
        for j in range(-(-ln // block_size)):
            tables[i, j] = nxt_blk
            nxt_blk += 1
    prompts = [rng.integers(0, cfg.vocab_size, ln).astype(np.int32)
               for ln in prompt_lens]
    tok = np.zeros((slots, 1), np.int32)
    pos = np.zeros((slots,), np.int32)
    for t in range(max(prompt_lens)):
        cur = np.array([[p[t] if t < len(p) else 0] for p in prompts],
                       np.int32)
        live = np.array([t < len(p) for p in prompts])
        logits, pool = model.decode_step(
            cfg, params, pool, jnp.asarray(cur),
            jnp.asarray(np.where(live, pos, 0)), ctx,
            block_tables=jnp.asarray(np.where(live[:, None], tables, 0)),
            block_size=block_size)
        nx = np.asarray(jnp.argmax(logits, -1), np.int32)
        tok = np.where(live[:, None], nx[:, None], tok)
        pos = np.where(live, pos + 1, pos)
    return pool, tables, tok, pos


def _stepwise_decode(cfg, ctx, params, cache, tables, tok, pos, budgets,
                     block_size, capacity, num_steps):
    """The PR-2 per-token path: T decode_step dispatches with host masking
    between steps (dead rows -> trash block), mirroring the serving loop."""
    cur, p = tok[:, 0].copy(), pos.copy()
    out = np.zeros((len(budgets), num_steps), np.int32)
    for t in range(num_steps):
        live = t < budgets
        logits, cache = model.decode_step(
            cfg, params, cache, jnp.asarray(cur[:, None]),
            jnp.asarray(np.where(live, np.minimum(p, capacity - 1), 0)),
            ctx,
            block_tables=jnp.asarray(np.where(live[:, None], tables, 0)),
            block_size=block_size)
        nx = np.asarray(jnp.argmax(logits, -1), np.int32)
        cur = np.where(live, nx, cur)
        out[:, t] = cur
        p = np.where(live, np.minimum(p + 1, capacity), p)
    return out, cache


def test_decode_loop_matches_stepwise_decode():
    """decode_loop(T) — one on-device scan — must emit token-identical
    output to T host-driven decode_step calls, across ragged budgets
    (mid-window completions park in the trash block) and an inactive slot,
    and leave a bitwise-identical KV pool behind."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap, T = 4, 16, 4
    cache, tables, tok, pos = _paged_decode_state(
        cfg, ctx, params, prompt_lens=[3, 5, 1], block_size=bs, capacity=cap)
    budgets = np.array([T, 2, 0], np.int32)     # full / mid-window / empty

    want, cache_ref = _stepwise_decode(
        cfg, ctx, params, jax.tree.map(jnp.copy, cache), tables, tok, pos,
        budgets, bs, cap, T)
    got, cache_win = model.decode_loop(
        cfg, params, jax.tree.map(jnp.copy, cache), jnp.asarray(tok),
        jnp.asarray(pos), jnp.asarray(budgets), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=T,
        capacity=cap)
    np.testing.assert_array_equal(np.asarray(got), want)
    for a, b in zip(jax.tree.leaves(cache_ref), jax.tree.leaves(cache_win)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_loop_past_capacity_clamps_like_stepwise():
    """A window running past ``capacity`` pins its writes to the last cell
    exactly like the per-token path (the contiguous-path clamp rule)."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap, T = 4, 8, 6
    cache, tables, tok, pos = _paged_decode_state(
        cfg, ctx, params, prompt_lens=[6, 4], block_size=bs, capacity=cap)
    budgets = np.array([T, T], np.int32)        # slot 0 crosses capacity
    want, _ = _stepwise_decode(
        cfg, ctx, params, jax.tree.map(jnp.copy, cache), tables, tok, pos,
        budgets, bs, cap, T)
    got, _ = model.decode_loop(
        cfg, params, jax.tree.map(jnp.copy, cache), jnp.asarray(tok),
        jnp.asarray(pos), jnp.asarray(budgets), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=T,
        capacity=cap)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_prefill_loop_matches_stepwise_prompt_feed():
    """The suffix-prefill scan (one dispatch) must write bitwise the KV a
    host-driven per-token prompt feed writes, and return the same first
    generated token — including a per-row *offset* start (the prefix-hit
    path: only the uncached suffix is fed) and an inactive pad row."""
    cfg = reduced_config(get_config("smollm-360m"))
    ctx = RunContext()
    params = model.init(cfg, KEY)
    bs, cap = 4, 16
    prompt_lens = [6, 9, 3]
    # reference: prompts written via per-token decode steps (host loop)
    cache_ref, tables, tok_ref, pos_ref = _paged_decode_state(
        cfg, ctx, params, prompt_lens, block_size=bs, capacity=cap)
    rng = np.random.default_rng(11)             # same stream -> same prompts
    prompts = [rng.integers(0, cfg.vocab_size, ln).astype(np.int32)
               for ln in prompt_lens]
    # scan path: same prompts, same tables, fresh pool, plus a pad row
    pool = model.init_paged_cache(cfg, 3, 3 * (cap // bs) + 1, bs)
    tmax = max(prompt_lens)
    toks = np.zeros((3, tmax), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    firsts, pool = model.prefill_loop(
        cfg, params, pool, jnp.asarray(toks),
        jnp.asarray(np.zeros(3, np.int32)),
        jnp.asarray(np.asarray(prompt_lens, np.int32)), ctx,
        block_tables=jnp.asarray(tables), block_size=bs, num_steps=tmax,
        capacity=cap)
    np.testing.assert_array_equal(np.asarray(firsts), tok_ref[:, 0])
    for a, b in zip(jax.tree.leaves(cache_ref), jax.tree.leaves(pool)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # offset restart: re-feed only the last 4 tokens of row 1's prompt
    # into the same pool (positions 5..8) — KV must stay bitwise stable
    # and the first token must reproduce (the restore-path invariant)
    sfx = np.zeros((3, 4), np.int32)
    sfx[1] = prompts[1][-4:]
    n_tok = np.array([0, 4, 0], np.int32)
    pos0 = np.array([0, prompt_lens[1] - 4, 0], np.int32)
    f2, pool2 = model.prefill_loop(
        cfg, params, pool, jnp.asarray(sfx), jnp.asarray(pos0),
        jnp.asarray(n_tok), ctx, block_tables=jnp.asarray(tables),
        block_size=bs, num_steps=4, capacity=cap)
    assert int(np.asarray(f2)[1]) == int(tok_ref[1, 0])
    for a, b in zip(jax.tree.leaves(cache_ref), jax.tree.leaves(pool2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_cache_rejects_non_full_attention():
    """Regression (ISSUE 4 satellite): paged KV requires full attention —
    both guard sites must keep raising a clean NotImplementedError for a
    windowed/recurrent config instead of silently mis-gathering."""
    cfg = reduced_config(get_config("recurrentgemma-2b"))
    assert cfg.window is not None               # local-attention config
    with pytest.raises(NotImplementedError, match="full attention"):
        model.init_paged_cache(cfg, 2, 9, 4)    # models/model.py guard
    # models/blocks.py guard: a decode step handed block tables on a
    # windowed config must refuse at trace time, whatever the cache is
    params = model.init(cfg, KEY)
    cache = model.init_cache(cfg, 2, 8)
    with pytest.raises(NotImplementedError, match="full attention"):
        model.decode_step(cfg, params, cache,
                          jnp.zeros((2, 1), jnp.int32),
                          jnp.zeros((2,), jnp.int32), RunContext(),
                          block_tables=jnp.zeros((2, 2), jnp.int32),
                          block_size=4)


def test_cache_logical_axes_match_cache_structure():
    for arch in list_archs():
        cfg = reduced_config(get_config(arch))
        if cfg.encoder_only:
            continue
        cache = model.abstract_cache(cfg, 2, 8)
        axes = model.cache_logical_axes(cfg)
        ok = jax.tree.map(lambda c, a: len(c.shape) == len(a), cache, axes)
        assert all(jax.tree.leaves(ok)), arch
