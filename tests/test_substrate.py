"""Substrate: optimizer, checkpointing, pipeline, compression, sharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config, reduced_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed.compression import (dequantize_int8,
                                           init_error_feedback,
                                           quantize_int8)
from repro.distributed.sharding import (DEFAULT_RULES, abstract_mesh,
                                        spec_for)
from repro.optim import adamw
from repro.optim.adamw import OptConfig


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #
def test_adamw_descends_quadratic():
    cfg = OptConfig(peak_lr=0.1, warmup_steps=1, decay_steps=1000,
                    weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, m = adamw.update(cfg, g, opt, params)
    assert float(loss(params)) < 0.01 * l0


def test_adamw_clips_gradients():
    cfg = OptConfig(clip_norm=1.0, warmup_steps=1)
    params = {"w": jnp.zeros(3)}
    opt = adamw.init(params)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, _, m = adamw.update(cfg, g, opt, params)
    assert float(m["grad_norm"]) == pytest.approx(1e6)


def test_schedule_warmup_and_decay():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=110,
                    min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.int32(110))) == pytest.approx(0.1)


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip_and_prune(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 3))}}
    for step in (10, 20, 30, 40):
        ckpt.save(d, step, tree, keep=2)
    assert ckpt.latest_step(d) == 40
    files = [f for f in os.listdir(d) if f.endswith(".npz")]
    assert len(files) == 2                       # pruned to keep=2
    step, restored = ckpt.restore(d, tree)
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"a": jnp.ones(4)})


def test_checkpoint_async(tmp_path):
    d = str(tmp_path)
    t = ckpt.save_async(d, 5, {"x": jnp.ones(2)})
    t.join(timeout=10)
    assert ckpt.latest_step(d) == 5


# --------------------------------------------------------------------------- #
# pipeline
# --------------------------------------------------------------------------- #
def test_pipeline_deterministic_and_resumable():
    cfg = reduced_config(get_config("smollm-360m"))
    p1 = Pipeline(cfg, DataConfig(4, 16, seed=7))
    p2 = Pipeline(cfg, DataConfig(4, 16, seed=7))
    b1, b2 = p1.batch(123), p2.batch(123)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.batch(124)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_pipeline_targets_shifted():
    cfg = reduced_config(get_config("smollm-360m"))
    p = Pipeline(cfg, DataConfig(2, 8))
    b = p.batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["targets"][:, :-1]))


# --------------------------------------------------------------------------- #
# compression
# --------------------------------------------------------------------------- #
def test_error_feedback_unbiased_over_steps():
    """EF residual keeps the cumulative quantized sum close to the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    residual = jnp.zeros(64)
    acc = np.zeros(64)
    for _ in range(50):
        v = g_true + residual
        q, s = quantize_int8(v)
        deq = dequantize_int8(q, s)
        residual = v - deq
        acc += np.asarray(deq)
    np.testing.assert_allclose(acc / 50, np.asarray(g_true), atol=1e-2)


# --------------------------------------------------------------------------- #
# sharding rules
# --------------------------------------------------------------------------- #
def test_spec_for_divisibility_and_uniqueness():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    P = jax.sharding.PartitionSpec
    # divisible dims get their preferred axes
    assert spec_for((16, 8), ("embed", "mlp"), mesh) == P("data", "model")
    # non-divisible fall back to replication (mixtral: 7 % 4 != 0)
    assert spec_for((7, 8), ("experts", "mlp"), mesh) == P(None, "model")
    # the same mesh axis is never used twice
    s = spec_for((8, 8), ("mlp", "vocab"), mesh)
    axes = [a for a in s if a is not None]
    assert len(axes) == len(set(axes)) <= 1 or axes == ["model"]


def test_spec_for_batch_tuple_rule():
    mesh = abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    P = jax.sharding.PartitionSpec
    assert spec_for((8, 4), ("batch", None), mesh) == P(("pod", "data"))
    # batch=1 cannot shard
    assert spec_for((1, 4), ("batch", None), mesh) == P()


def test_param_specs_cover_all_archs():
    from repro.configs import list_archs
    from repro.launch import steps as S
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in list_archs():
        cfg = reduced_config(get_config(arch))
        sh = S.state_shardings(cfg, mesh)       # must not raise
        assert jax.tree.leaves(sh)
