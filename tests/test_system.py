"""End-to-end behaviour: the paper's headline claims, at test scale, plus
fleet fault-tolerance paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import (ExecutionController, FaultPlan, Policy,
                        RemoteableMethod)
from repro.data.pipeline import DataConfig
from repro.launch.train import FleetTrainer
from repro.launch.serve import Request, ServingEngine


def _heavy_method():
    def fn(n):
        # compute-bound synthetic workload (N-queens-like): O(n * 4096^... )
        x = jnp.ones((64, 64)) * (1.0 / n)

        def body(i, acc):
            return jnp.tanh(acc @ x + i)

        return jax.lax.fori_loop(0, n * 50, body, x).sum()

    return RemoteableMethod("heavy", fn, size_fn=lambda n: n)


def test_offload_speedup_for_compute_bound_work():
    """Paper §7.3: compute-bound work offloaded to the cloud is faster and
    cheaper (orders of magnitude at app scale)."""
    ec = ExecutionController(policy=Policy.EXEC_TIME, link="wifi-local")
    rm = _heavy_method()
    local = ec.execute(rm, 40, force="local")
    remote = ec.execute(rm, 40, force="remote")
    assert remote.time_s < local.time_s
    assert remote.energy_j < local.energy_j
    speedup = local.time_s / remote.time_s
    assert speedup > 2.0                      # venue ratio >> transfer cost


def test_biv_exists_and_grows_with_rtt():
    """Paper Tables 3-4: a boundary input value exists; 3G BIV >= WiFi BIV."""
    rm = _heavy_method()

    def biv(link):
        ec = ExecutionController(policy=Policy.EXEC_TIME, link=link)
        for n in (1, 2, 4, 8, 16, 32, 64):
            l = ec.execute(rm, n, force="local")
            r = ec.execute(rm, n, force="remote")
            if r.time_s < l.time_s:
                return n
        return 10 ** 9

    b_wifi = biv("wifi-local")
    b_3g = biv("3g")
    assert b_wifi < 10 ** 9
    assert b_3g >= b_wifi


def test_parallelization_reduces_time(tmp_path):
    """Paper §7.4: k clones reduce execution time for parallelizable work."""
    from repro.core import split_batch
    from repro.core.clones import CloneState
    ec = ExecutionController(policy=Policy.EXEC_TIME, link="wifi-local")
    # provision RUNNING clones: isolates the split/makespan logic from
    # resume overhead (which legitimately dominates small tasks — §7.4)
    ec.pool.provision("main", 8, state=CloneState.RUNNING)

    def fn(xs):
        # work proportional to the shard size (splittable workload)
        def body(i, acc):
            return jnp.tanh(acc + xs[i % xs.shape[0]])

        return jax.lax.fori_loop(0, xs.shape[0] * 250, body, jnp.zeros(
            xs.shape[1:])).sum()

    rm = RemoteableMethod(
        "par", fn, size_fn=lambda xs: xs.size,
        split_fn=lambda args, k: split_batch(args, k),
        merge_fn=lambda vs: sum(float(v) for v in vs))
    x = jnp.ones((8, 128, 128))
    t1 = ec.execute(rm, x, force="remote", n_clones=1).time_s
    t4 = ec.execute(rm, x, force="remote", n_clones=4).time_s
    assert t4 < t1


def test_fleet_trainer_restart_from_fault(tmp_path):
    cfg = reduced_config(get_config("smollm-360m"))
    trainer = FleetTrainer(
        cfg, steps_total=8, data_cfg=DataConfig(2, 16),
        ckpt_dir=str(tmp_path), ckpt_every=2,
        fault_plan=FaultPlan(fail_every=5))
    trainer.run()
    assert trainer.report.steps_done == 8
    assert trainer.report.restarts >= 1        # hit the fault + recovered


def test_fleet_trainer_resumes_from_checkpoint(tmp_path):
    cfg = reduced_config(get_config("smollm-360m"))
    t1 = FleetTrainer(cfg, steps_total=4, data_cfg=DataConfig(2, 16),
                      ckpt_dir=str(tmp_path), ckpt_every=2)
    s1 = t1.run()
    t2 = FleetTrainer(cfg, steps_total=8, data_cfg=DataConfig(2, 16),
                      ckpt_dir=str(tmp_path), ckpt_every=2)
    t2.run()
    assert t2.report.restarts == 1             # restored, not from scratch
    assert t2.report.steps_done == 4           # only the remaining steps


def test_training_loss_decreases():
    cfg = reduced_config(get_config("smollm-360m"))
    # overfit tiny fixed batch: loss must drop clearly
    from repro.launch import steps as S
    from repro.models import model
    from repro.models.context import RunContext
    from repro.optim import adamw
    from repro.optim.adamw import OptConfig

    ctx = RunContext()
    step = jax.jit(S.build_train_step(
        cfg, OptConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=100), ctx))
    params = model.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init(params)}
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_serving_engine_end_to_end():
    cfg = reduced_config(get_config("smollm-360m"))
    eng = ServingEngine(cfg, policy=Policy.EXEC_TIME, capacity=64)
    reqs = [Request(i, np.arange(6, dtype=np.int32) + i, 4)
            for i in range(3)]
    comps = eng.serve_batch(reqs)
    assert len(comps) == 3
    assert all(len(c.tokens) == 4 for c in comps)
    assert eng.stats["requests"] == 3


def test_continuous_batching_matches_serial_path():
    """The event-driven ClientHandler must emit exactly the tokens the old
    batch-serial path emits — both for a fused cohort and across a
    step-granularity leave (the survivor keeps decoding alone)."""
    from repro.core.scheduler import ServeRequest
    from repro.launch.serve import ClientHandler, LMBackend

    cfg = reduced_config(get_config("smollm-360m"))
    backend = LMBackend(cfg, capacity=32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(4)]

    eng = ServingEngine(cfg, capacity=32, backend=backend)
    serial = eng.serve_batch([Request(i, p, 4)
                              for i, p in enumerate(prompts)], force="local")
    serial_tokens = {c.rid: c.tokens for c in serial}

    handler = ClientHandler(backend, max_batch=4, prompt_pad=6)
    rep = handler.run([ServeRequest(i, p, 4, arrival_t=0.0)
                       for i, p in enumerate(prompts)])
    assert {c.rid: c.tokens for c in rep.completions} == serial_tokens

    # ragged token budgets: rid 0 leaves after 2 steps, rid 1 decodes on —
    # both KV modes must match the serial path token-for-token
    serial2 = eng.serve_batch([Request(0, prompts[0], 2),
                               Request(1, prompts[1], 5)], force="local")
    s2 = {c.rid: c.tokens for c in serial2}
    for kv in ("paged", "contiguous"):
        handler2 = ClientHandler(backend, max_batch=2, prompt_pad=6, kv=kv)
        rep2 = handler2.run([ServeRequest(0, prompts[0], 2),
                             ServeRequest(1, prompts[1], 5)])
        c2 = {c.rid: c.tokens for c in rep2.completions}
        assert c2[0] == s2[0][:2]
        assert c2[1] == s2[1]


def test_decode_window_matches_serial_path_real_model():
    """Acceptance (ISSUE 3): the fused decode window — ragged budgets, a
    mid-window completion, a mid-flight join — serves exactly the tokens of
    the batch-serial path, while dispatching T tokens per device round-trip."""
    from repro.core.scheduler import ServeRequest
    from repro.launch.serve import ClientHandler, LMBackend

    cfg = reduced_config(get_config("smollm-360m"))
    backend = LMBackend(cfg, capacity=32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(3)]
    eng = ServingEngine(cfg, capacity=32, backend=backend)
    s1 = {c.rid: c.tokens for c in eng.serve_batch(
        [Request(0, prompts[0], 2), Request(1, prompts[1], 7)],
        force="local")}
    s2 = {c.rid: c.tokens for c in eng.serve_batch(
        [Request(2, prompts[2], 5)], force="local")}

    handler = ClientHandler(backend, max_batch=2, prompt_pad=6,
                            decode_window=4,
                            executor=lambda c, f, a: (f(*a), 0.5))
    rep = handler.run([ServeRequest(0, prompts[0], 2, arrival_t=0.0),
                       ServeRequest(1, prompts[1], 7, arrival_t=0.0),
                       ServeRequest(2, prompts[2], 5, arrival_t=1.2)])
    got = {c.rid: c.tokens for c in rep.completions}
    assert got[0] == s1[0][:2]                  # mid-window completion
    assert got[1] == s1[1]
    assert got[2] == s2[2][:5]                  # mid-flight join, own slots


def test_mid_flight_join_faster_ttft_and_token_identical():
    """Acceptance (ISSUE 2): a request arriving while a cohort is mid-decode
    is admitted into a free slot at the next decode step, its TTFT is
    strictly lower than under step-boundary fusion, and its tokens are
    identical to running it in a fresh cohort.  Deterministic VirtualClock:
    the executor pins every venue call to 0.5s."""
    from repro.core.scheduler import ServeRequest
    from repro.launch.serve import ClientHandler, LMBackend

    cfg = reduced_config(get_config("smollm-360m"))
    backend = LMBackend(cfg, capacity=32)
    rng = np.random.default_rng(7)
    pA = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
    pB = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
    ex = lambda c, f, a: (f(*a), 0.5)           # noqa: E731

    def run(kv):
        h = ClientHandler(backend, max_batch=2, prompt_pad=6,
                          max_secondaries=0, kv=kv, executor=ex)
        return h.run([ServeRequest(0, pA, 8, arrival_t=0.0),
                      ServeRequest(1, pB, 4, arrival_t=1.2)])

    rep_p, rep_c = run("paged"), run("contiguous")
    paged = {c.rid: c for c in rep_p.completions}
    fused = {c.rid: c for c in rep_c.completions}
    # fresh-cohort (unfused) reference run of B alone
    h_solo = ClientHandler(backend, max_batch=1, prompt_pad=6,
                           max_secondaries=0, executor=ex)
    solo = h_solo.run([ServeRequest(1, pB, 4, arrival_t=0.0)])
    assert paged[1].ttft_s < fused[1].ttft_s
    assert paged[1].tokens == solo.completions[0].tokens
    assert paged[0].tokens == fused[0].tokens
    # paged reserves blocks as tokens are written; contiguous reserves
    # rows x capacity up front
    assert rep_p.kv_util > rep_c.kv_util


def test_prefix_cache_token_identical_lower_ttft_under_scarcity():
    """Acceptance (ISSUE 4): with prefix sharing enabled, generated tokens
    are bit-identical to the unshared baseline for the same trace, while
    prefix hits land (> 0) and a late same-prefix request's TTFT is
    strictly lower — under block scarcity the baseline queues it for a
    retirement, the prefix cache admits it on its private blocks alone.
    Deterministic: VirtualClock + fixed 0.5 s venue cost."""
    from repro.core.scheduler import ServeRequest
    from repro.launch.serve import ClientHandler, LMBackend

    cfg = reduced_config(get_config("smollm-360m"))
    backend = LMBackend(cfg, capacity=32)
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    tails = [rng.integers(0, cfg.vocab_size, 4, dtype=np.int32)
             for _ in range(3)]
    prompts = [np.concatenate([prefix, t]) for t in tails]
    ex = lambda c, f, a: (f(*a), 0.5)           # noqa: E731

    def run(prefix_cache):
        # 8 real blocks of 4: one 12-token prompt + 6 new = 5 blocks, so
        # two unshared requests cannot decode side by side for long
        h = ClientHandler(backend, max_batch=3, prompt_pad=12,
                          max_secondaries=0, block_size=4, num_blocks=9,
                          prefix_cache=prefix_cache, executor=ex)
        reqs = [ServeRequest(i, prompts[i], 6, arrival_t=1.1 * i)
                for i in range(3)]
        return h.run(reqs)

    rep_s = run(True)
    rep_u = run(False)
    shared = {c.rid: c for c in rep_s.completions}
    unshared = {c.rid: c for c in rep_u.completions}
    assert len(shared) == len(unshared) == 3
    for rid in range(3):
        assert shared[rid].tokens == unshared[rid].tokens   # bit-identical
    assert rep_s.prefix_hit_rate > 0.0 and rep_u.prefix_hit_rate == 0.0
    # the late same-prefix arrivals enter service sooner when their
    # prefix is already resident (2 shared full blocks each)
    assert shared[2].ttft_s < unshared[2].ttft_s
    assert rep_s.kv_reserved_peak <= rep_u.kv_reserved_peak


def test_preemption_restores_token_identical():
    """Acceptance (ISSUE 4): a pool too tight for the offered concurrency
    completes every request via preempt + prefix-accelerated restore —
    zero RuntimeError — and every request's tokens are identical to a
    roomy-pool run of the same trace."""
    from repro.core.scheduler import ServeRequest
    from repro.launch.serve import ClientHandler, LMBackend

    cfg = reduced_config(get_config("smollm-360m"))
    backend = LMBackend(cfg, capacity=32)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(3)]
    ex = lambda c, f, a: (f(*a), 0.5)           # noqa: E731

    def run(num_blocks):
        h = ClientHandler(backend, max_batch=3, prompt_pad=8,
                          max_secondaries=0, block_size=4,
                          num_blocks=num_blocks, executor=ex)
        reqs = [ServeRequest(i, prompts[i], 10, arrival_t=0.0)
                for i in range(3)]
        return h.run(reqs)

    roomy = run(None)                           # worst-case-sized pool
    # 6 real blocks; each request needs 5 (8 prompt + 10 new = 18 tokens)
    tight = run(7)
    r = {c.rid: c.tokens for c in roomy.completions}
    t = {c.rid: c.tokens for c in tight.completions}
    assert roomy.preemptions == 0
    assert tight.preemptions > 0 and tight.restored_tokens > 0
    assert len(t) == 3 and t == r               # identical under pressure


def test_fleet_escalation_token_identical_to_pinned_large():
    """Acceptance (ISSUE 5): a KV-hungry request escalated live to a
    bigger clone type completes token-identical to the same trace pinned
    at the large tier, while the bulk stays on the cheap tier —
    heterogeneity is an economics decision, never a correctness one.
    Deterministic: VirtualClock + fixed 0.2 s venue cost."""
    from repro.core.scheduler import ServeRequest
    from repro.launch.serve import ClientHandler, LMBackend

    cfg = reduced_config(get_config("smollm-360m"))
    backend = LMBackend(cfg, capacity=32)
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
               for _ in range(3)]
    ex = lambda c, f, a: (f(*a), 0.2)           # noqa: E731

    def trace():
        # rid 0 needs ceil(min(6+24, 32)/4) = 8 blocks > basic's 3 real
        return [ServeRequest(0, prompts[0], 24, arrival_t=0.0),
                ServeRequest(1, prompts[1], 4, arrival_t=0.0),
                ServeRequest(2, prompts[2], 4, arrival_t=0.1)]

    h = ClientHandler(backend, clone_type="basic", fleet=["basic", "large"],
                      max_batch=2, prompt_pad=6, block_size=4, num_blocks=4,
                      use_primary=False, max_secondaries=3, executor=ex)
    rep = h.run(trace())
    pinned = ClientHandler(backend, clone_type="large", max_batch=2,
                           prompt_pad=6, block_size=4,
                           use_primary=False, max_secondaries=3, executor=ex)
    rep_l = pinned.run(trace())
    got = {c.rid: c.tokens for c in rep.completions}
    ref = {c.rid: c.tokens for c in rep_l.completions}
    assert len(got) == len(ref) == 3
    assert rep.escalations >= 1
    assert got == ref                           # escalation is transparent
    assert rep.fleet_mix.get("large", 0) >= 1   # the escalated request
    assert rep.fleet_mix.get("basic", 0) >= 1   # the bulk
    # the pinned-large fleet bills every clone-second at the dear tier
    assert set(rep_l.clone_seconds_by_type) == {"large", "main"}


def test_serving_engine_stats_aggregate_decode_steps():
    """offloaded/escalations must reflect every step in the batch, not just
    the prefill result."""
    cfg = reduced_config(get_config("smollm-360m"))
    eng = ServingEngine(cfg, capacity=32)
    reqs = [Request(0, np.arange(6, dtype=np.int32), 3)]
    eng.serve_batch(reqs, force="remote")
    # prefill + 3 decode steps, all forced remote
    assert eng.stats["offloaded"] == 4
    eng.serve_batch(reqs, force="local")
    assert eng.stats["offloaded"] == 4          # unchanged by local batch


def test_serving_deterministic_across_placements():
    """Local and offloaded execution return identical tokens (correctness
    of transparent offloading — the paper's §4.4 contract)."""
    cfg = reduced_config(get_config("smollm-360m"))
    eng = ServingEngine(cfg, capacity=64)
    reqs = [Request(0, np.arange(8, dtype=np.int32), 4)]
    a = eng.serve_batch(reqs, force="local")[0].tokens
    b = eng.serve_batch(reqs, force="remote")[0].tokens
    assert a == b
