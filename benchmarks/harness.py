"""Shared measurement harness for the paper-reproduction benchmarks.

Methodology mirrors §7: each point is the average of ``reps`` runs (the
paper uses 20 with 30 s pauses; we default lower for CI practicality —
``REPRO_FULL=1`` restores paper-grade repetitions).  Times are venue-model
scenario seconds (DESIGN.md §2: measured host wall-clock x venue ratio +
modeled transfer/provisioning); energies come from the paper's PowerTutor
coefficients.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

from repro.core import ExecutionController, Policy

REPS = 5 if os.environ.get("REPRO_FULL") else 2
SCENARIOS = ("phone", "wifi-local", "wifi-internet", "3g")


def controller_for(scenario: str, provision: int = 8) -> ExecutionController:
    link = "wifi-local" if scenario == "phone" else scenario
    ec = ExecutionController(policy=Policy.EXEC_TIME, link=link)
    ec.pool.provision("main", provision)
    return ec


def measure(ec: ExecutionController, rm, *args, scenario: str,
            n_clones: int = 1, reps: int = None) -> Dict[str, float]:
    """Average scenario time/energy over reps."""
    reps = reps or REPS
    force = "local" if scenario == "phone" else "remote"
    t = e = overhead = 0.0
    comps: Dict[str, float] = {}
    res = None
    for _ in range(reps):
        res = ec.execute(rm, *args, force=force, n_clones=n_clones)
        t += res.time_s
        e += res.energy_j
        overhead += res.overhead_s
        for k, v in res.energy.items():
            comps[k] = comps.get(k, 0.0) + v
    out = {"time_s": t / reps, "energy_j": e / reps,
           "overhead_s": overhead / reps,
           "tx": res.tx_bytes, "rx": res.rx_bytes,
           "n_clones": res.n_clones}
    out["energy_components"] = {k: v / reps for k, v in comps.items()}
    return out


def find_biv(rm, sizes, link: str) -> Optional[int]:
    """Boundary input value: smallest size where offloading pays (Table 3)."""
    ec = ExecutionController(policy=Policy.EXEC_TIME, link=link)
    for n in sizes:
        local = ec.execute(rm, n, force="local")
        remote = ec.execute(rm, n, force="remote")
        if remote.time_s < local.time_s:
            return n
    return None
