"""JAX implementations of the paper's benchmark programs (§7).

Micro-benchmarks (Great Computer Language Shootout, Table 3), realistic
benchmarks (Computer Language Benchmark Game, Table 4), and the five
application benchmarks (§7.3).  Each is registered as a RemoteableMethod
with the same asymptotic complexity as the original; Java-object-oriented
micro-benchmarks (methcall/objinst/binarytrees) map to JAX analogues of the
same complexity (noted inline).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import RemoteableMethod, split_batch


# --------------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------------- #
def _work_loop(iters, size=64):
    """Compute-bound inner loop: iters fused matvec steps."""
    x = jnp.full((size,), 0.5)
    m = jnp.eye(size) * 0.99 + 0.01

    def body(i, acc):
        return jnp.tanh(m @ acc + 1e-6 * i)

    return jax.lax.fori_loop(0, iters, body, x).sum()


# --------------------------------------------------------------------------- #
# Table 3 micro-benchmarks (complexity-faithful)
# --------------------------------------------------------------------------- #
def fibonacci(n):
    """O(2^n): cost of the naive recursion, evaluated iteratively."""
    iters = jnp.asarray(1.618 ** jnp.clip(n, 0, 30), jnp.int32)
    return _work_loop(iters)


def hash_bench(n):
    """O(n^2 log n): repeated sorting of n keys, n times."""
    keys = (jnp.arange(n) * 1103515245 % 2 ** 16).astype(jnp.int32)

    def body(i, acc):
        return jnp.sort(acc + i)

    return jax.lax.fori_loop(0, n, body, keys).sum()


def hash2(n):
    """O(n log n): one sort of n keys."""
    keys = (jnp.arange(n * 100) * 1103515245 % 2 ** 16).astype(jnp.int32)
    return jnp.sort(keys)[-1]


def matrix(n):
    """O(n): chain of fixed-size matmuls, n links."""
    return _work_loop(n, size=30)


def methcall(n):
    """O(n): n dependent scalar ops (dynamic-dispatch analogue)."""
    return _work_loop(n, size=8)


def nestedloop(n):
    """O(n^6): six nested loops of range n."""
    iters = jnp.asarray(jnp.clip(n, 0, 12) ** 6, jnp.int32)
    return _work_loop(iters, size=8)


def objinst(n):
    """O(n): n small allocations+init (object instantiation analogue)."""
    return _work_loop(n, size=8)


def sieve(n):
    """O(n): sieve of Eratosthenes over n*1000 integers (vectorized)."""
    m = n * 1000
    nums = jnp.arange(2, m + 2)
    is_prime = jnp.ones_like(nums, dtype=bool)
    for p in (2, 3, 5, 7, 11, 13):
        is_prime &= (nums <= p) | (nums % p != 0)
    return is_prime.sum()


# --------------------------------------------------------------------------- #
# Table 4 realistic benchmarks
# --------------------------------------------------------------------------- #
def binarytrees(n):
    """O(2^n) allocations: tree build/teardown analogue."""
    iters = jnp.asarray(2 ** jnp.clip(n, 0, 22), jnp.int32)
    return _work_loop(iters, size=8)


def knucleotide(n):
    """k-mer counting over a 4-letter sequence of length n*10000."""
    m = n * 10_000
    seq = (jnp.arange(m) * 1103515245 % 4).astype(jnp.int32)
    k4 = seq[:-3] * 64 + seq[1:-2] * 16 + seq[2:-1] * 4 + seq[3:]
    counts = jnp.zeros((256,), jnp.int32).at[k4].add(1)
    return counts.max()


def mandelbrot(n):
    """Mandelbrot escape iteration on an (n x n) grid."""
    xs = jnp.linspace(-2.0, 0.5, n)
    ys = jnp.linspace(-1.25, 1.25, n)
    c = xs[None, :] + 1j * ys[:, None]

    def body(i, zk):
        z, k = zk
        z = z * z + c
        k = k + (jnp.abs(z) < 2.0)
        return z, k

    _, k = jax.lax.fori_loop(0, 50, body,
                             (jnp.zeros_like(c), jnp.zeros(c.shape,
                                                           jnp.int32)))
    return k.sum()


def nbody(n):
    """n simulation steps of a 16-body system."""
    pos = jnp.stack([jnp.sin(jnp.arange(16.0)), jnp.cos(jnp.arange(16.0)),
                     jnp.sin(jnp.arange(16.0) * 2)], 1)
    vel = jnp.zeros_like(pos)

    def step(i, pv):
        p, v = pv
        d = p[:, None] - p[None, :]
        r2 = (d ** 2).sum(-1) + 1e-3
        f = (d / (r2 ** 1.5)[..., None]).sum(1)
        v = v - 0.001 * f
        return p + 0.001 * v, v

    p, v = jax.lax.fori_loop(0, n, step, (pos, vel))
    return (p ** 2).sum()


def spectralnorm(n):
    """Power iteration on the (n x n) infinite-matrix A of the benchmark."""
    i = jnp.arange(n, dtype=jnp.float32)
    a = 1.0 / ((i[:, None] + i[None, :]) * (i[:, None] + i[None, :] + 1) / 2
               + i[:, None] + 1)
    u = jnp.ones((n,))
    for _ in range(10):
        v = a.T @ (a @ u)
        u = v / jnp.linalg.norm(v)
    return jnp.sqrt(u @ (a.T @ (a @ u)) / (u @ u))


# --------------------------------------------------------------------------- #
# Application benchmarks (§7.3)
# --------------------------------------------------------------------------- #
def nqueens(n, lo, hi):
    """Count N-queens solutions over candidate range [lo, hi).

    The paper's reduced brute force (one queen per column, n^n candidates);
    the range split across clones mirrors 'allocating different regions of
    the board to different clones'.
    """
    chunk = 1 << 14
    count = jnp.zeros((), jnp.int32)
    lo_i, hi_i = int(lo), int(hi)
    n_chunks = max(1, -(-(hi_i - lo_i) // chunk))

    def body(ci, acc):
        idx = lo_i + ci * chunk + jnp.arange(chunk)
        valid = idx < hi_i
        d = (idx[:, None] // (n ** jnp.arange(n))) % n     # (C, n) rows
        ok = jnp.ones(idx.shape[0], bool)
        for i in range(n):
            for j in range(i + 1, n):
                ok &= (d[:, i] != d[:, j]) & \
                    (jnp.abs(d[:, i] - d[:, j]) != (j - i))
        return acc + jnp.sum(ok & valid)

    return jax.lax.fori_loop(0, n_chunks, body, count)


def sudoku(puzzle):
    """Constraint-propagation solver (singles elimination to fixpoint)."""
    grid = puzzle.astype(jnp.int32)                 # (9,9), 0 = empty
    rows, cols = jnp.arange(9), jnp.arange(9)
    boxes = (rows[:, None] // 3) * 3 + cols[None, :] // 3

    def allowed_mask(g):
        onehot = jax.nn.one_hot(g, 10, dtype=jnp.int32)[..., 1:]  # (9,9,9)
        row_used = onehot.sum(1)                    # (9, 9digits)
        col_used = onehot.sum(0)
        box_used = jnp.zeros((9, 9), jnp.int32).at[boxes.reshape(-1)].add(
            onehot.reshape(81, 9))
        cand = (row_used[:, None, :] == 0) & (col_used[None, :, :] == 0) \
            & (box_used[boxes] == 0)
        return cand & (g[..., None] == 0)

    def step(i, g):
        cand = allowed_mask(g)
        n_cand = cand.sum(-1)
        single = (n_cand == 1) & (g == 0)
        digit = cand.argmax(-1) + 1
        return jnp.where(single, digit, g)

    solved = jax.lax.fori_loop(0, 64, step, grid)
    return solved, (solved > 0).all()


def make_face_detector(key=None):
    """Tiny convnet 'face detector': returns (params, fn(images)->counts)."""
    key = key or jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (3, 3, 1, 8)) * 0.3
    w2 = jax.random.normal(k2, (3, 3, 8, 1)) * 0.3

    def detect(images):                              # (N, 64, 64)
        x = images[..., None]
        x = jax.lax.conv_general_dilated(x, w1, (1, 1), "SAME",
                                         dimension_numbers=("NHWC", "HWIO",
                                                            "NHWC"))
        x = jax.nn.relu(x)
        x = jax.lax.conv_general_dilated(x, w2, (2, 2), "SAME",
                                         dimension_numbers=("NHWC", "HWIO",
                                                            "NHWC"))
        heat = jax.nn.sigmoid(x[..., 0])
        return (heat > 0.7).sum(axis=(1, 2))         # per-image "faces"

    return detect


def make_virus_scanner(n_sigs=64, sig_len=8):
    """Multi-pattern scanner: count signature hits across files."""
    rng = np.random.default_rng(0)
    sigs = jnp.asarray(rng.integers(0, 256, (n_sigs, sig_len)), jnp.int32)

    def scan(files):                                 # (n_files, file_len)
        def scan_one(fbytes):
            win = jnp.stack([fbytes[i:i + fbytes.shape[0] - sig_len + 1]
                             for i in range(sig_len)], -1)   # (P, L)
            eq = (win[:, None, :] == sigs[None, :, :]).all(-1)
            return eq.sum()

        return jax.lax.map(scan_one, files).sum()

    return scan


def image_combiner(img1, img2):
    """Paper §7.3: naive side-by-side combine (big allocation)."""
    h = max(img1.shape[0], img2.shape[0])
    w = img1.shape[1] + img2.shape[1]
    canvas = jnp.zeros((h, w), img1.dtype)
    canvas = canvas.at[:img1.shape[0], :img1.shape[1]].set(img1)
    canvas = canvas.at[:img2.shape[0], img1.shape[1]:].set(img2)
    return canvas


# --------------------------------------------------------------------------- #
# RemoteableMethod registry for the benchmarks
# --------------------------------------------------------------------------- #
def micro_methods():
    mk = lambda name, fn: RemoteableMethod(name, fn, size_fn=lambda n: n,
                                           static_args=(0,))
    return {
        "fibonacci": mk("fibonacci", fibonacci),
        "hash": mk("hash", hash_bench),
        "hash2": mk("hash2", hash2),
        "matrix": mk("matrix", matrix),
        "methcall": mk("methcall", methcall),
        "nestedloop": mk("nestedloop", nestedloop),
        "objinst": mk("objinst", objinst),
        "sieve": mk("sieve", sieve),
    }


MICRO_COMPLEXITY = {
    "fibonacci": "O(2^n)", "hash": "O(n^2 log n)", "hash2": "O(n log n)",
    "matrix": "O(n)", "methcall": "O(n)", "nestedloop": "O(n^6)",
    "objinst": "O(n)", "sieve": "O(n)",
}


def realistic_methods():
    mk = lambda name, fn: RemoteableMethod(name, fn, size_fn=lambda n: n,
                                           static_args=(0,))
    return {
        "binarytrees": mk("binarytrees", binarytrees),
        "knucleotide": mk("knucleotide", knucleotide),
        "mandelbrot": mk("mandelbrot", mandelbrot),
        "nbody": mk("nbody", nbody),
        "spectralnorm": mk("spectralnorm", spectralnorm),
    }


def nqueens_method(n=8):
    def fn(lo, hi):
        return nqueens(n, lo, hi)

    def split(args, k):
        from repro.core import split_range
        lo, hi = args
        return split_range(int(lo), int(hi), k)

    return RemoteableMethod("nqueens", fn, size_fn=lambda lo, hi: hi - lo,
                            split_fn=split, static_args=(0, 1),
                            merge_fn=lambda vs: sum(int(v) for v in vs))


def face_detection_method():
    detect = make_face_detector()
    return RemoteableMethod(
        "face_detection", detect, size_fn=lambda imgs: imgs.shape[0],
        split_fn=lambda args, k: split_batch(args, k),
        merge_fn=lambda vs: np.concatenate([np.asarray(v) for v in vs]))


def virus_scan_method():
    scan = make_virus_scanner()
    return RemoteableMethod(
        "virus_scan", scan, size_fn=lambda files: files.size,
        split_fn=lambda args, k: split_batch(args, k),
        merge_fn=lambda vs: sum(int(v) for v in vs))


def image_combiner_method():
    return RemoteableMethod(
        "image_combiner", image_combiner,
        size_fn=lambda a, b: a.size + b.size,
        mem_fn=lambda a, b: 4 * max(a.shape[0], b.shape[0])
        * (a.shape[1] + b.shape[1]) * 16)   # 16x overhead: naive bitmap ops
