"""Serving under offered load: the Client Handler's elasticity, measured.

Sweeps Poisson arrival rates against the event-driven continuous-batching
``ClientHandler`` (paper §5.2-§5.3) on the virtual timeline and reports,
per load level: p50/p99 request latency, p50 time-to-first-token,
throughput (tokens/s), client-side shed rate, clone-pool activity
(resumes/boots/pauses), busy energy, and the autoscaler's peak secondary
count.  The final high-load level must show the autoscaler provisioning
multiple secondaries; every level ends with an idle drain past the pause
TTL so the elastic shrink is visible too.

    PYTHONPATH=src python benchmarks/serving_load.py
    PYTHONPATH=src python benchmarks/serving_load.py --rates 1 4 16

All times are virtual-clock seconds (venue-model execution + modeled
transfer + provisioning); nothing here sleeps for real.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, reduced_config            # noqa: E402
from repro.core.clones import PAUSE_IDLE_TTL                    # noqa: E402
from repro.core.scheduler import poisson_arrivals               # noqa: E402
from repro.launch.serve import ClientHandler, LMBackend         # noqa: E402


def run_sweep(arch: str = "smollm-360m", rates=(0.5, 4.0, 32.0),
              n_requests: int = 32, max_batch: int = 4,
              max_secondaries: int = 6, new_tokens: int = 6,
              prompt_len: int = 6):
    cfg = reduced_config(get_config(arch))
    backend = LMBackend(cfg, capacity=32)
    header = (f"{'rate_rps':>8s} {'served':>6s} {'shed':>5s} "
              f"{'p50_s':>8s} {'p99_s':>8s} {'ttft50_s':>8s} "
              f"{'tok/s':>7s} {'peak_2nd':>8s} {'resumes':>7s} "
              f"{'pauses':>6s} {'busy_J':>9s}")
    lines = [header]
    reports = []
    for rate in rates:
        handler = ClientHandler(backend, max_batch=max_batch,
                                max_secondaries=max_secondaries,
                                prompt_pad=prompt_len)
        reqs = poisson_arrivals(rate, n_requests, seed=0,
                                prompt_len=prompt_len,
                                vocab=cfg.vocab_size,
                                max_new_tokens=new_tokens)
        report = handler.run(reqs, drain_idle_s=PAUSE_IDLE_TTL + 5.0)
        still_running = len(handler.pool.running_secondaries())
        lines.append(
            f"{rate:>8.2f} {len(report.completions):>6d} "
            f"{report.rejected:>5d} {report.p50_latency_s:>8.3f} "
            f"{report.p99_latency_s:>8.3f} {report.p50_ttft_s:>8.3f} "
            f"{report.tokens_per_s:>7.2f} {report.peak_secondaries:>8d} "
            f"{report.pool_stats['resumes']:>7d} "
            f"{report.pool_stats['pauses']:>6d} "
            f"{report.busy_energy_j:>9.2f}")
        reports.append((rate, report, still_running))
    return lines, reports


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[0.5, 4.0, 32.0])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--secondaries", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=6)
    args = ap.parse_args()

    lines, reports = run_sweep(args.arch, tuple(args.rates), args.requests,
                               args.batch, args.secondaries, args.new_tokens)
    print("\n".join(lines))

    hi_rate, hi, still_running = reports[-1]
    print(f"\nhigh load ({hi_rate} req/s): autoscaler peaked at "
          f"{hi.peak_secondaries} secondaries "
          f"({hi.pool_stats['resumes']} resumes, "
          f"{hi.pool_stats['boots']} boots); after the idle drain "
          f"{still_running} remain running "
          f"({hi.pool_stats['pauses']} TTL pauses).")
    # acceptance check — only meaningful when the offered load is actually
    # high and the cap allows elasticity
    if args.secondaries >= 2 and hi_rate >= 2.0 and args.requests >= 8:
        assert hi.peak_secondaries >= 2, \
            "autoscaler failed to provision secondaries under high load"
    assert still_running == 0, "idle TTL failed to pause the secondaries"
    lo = reports[0][1]
    print(f"latency under load: p99 {lo.p99_latency_s:.3f}s @ "
          f"{reports[0][0]} req/s -> {hi.p99_latency_s:.3f}s @ "
          f"{hi_rate} req/s")


if __name__ == "__main__":
    main()
